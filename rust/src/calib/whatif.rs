//! The what-if engine: measured compute on hypothetical fabrics.
//!
//! The paper's payoff is predictive — its PCIe/NVLink/10GbE/InfiniBand
//! study asks "what would this workload cost on that interconnect". PR 3
//! closed the trace → [`CalibratedProfile`] → replay loop, but replay
//! only reproduces the measured hardware. This module completes the
//! other half: keep an entry's *measured* per-layer compute costs and
//! fitted framework overhead, substitute a **hypothetical** collective
//! channel (a cluster preset, a named inter-node fabric, an explicit
//! α–β pair, or the degenerate ideal channel), rebuild the S-SGD DAG via
//! `builder::build_with` and simulate it under any scheduler — the α–β
//! comm analysis shared with arXiv:1711.05979 applied forward instead of
//! backward.
//!
//! Contracts the tests pin:
//!
//! * [`Fabric::Measured`] passes **no** comm substitution, so a what-if
//!   prediction on the measured fabric is the same code path as
//!   [`replay::replay_entry`] — bit-identical by construction.
//! * [`Fabric::Ideal`] (zero-α, infinite-bandwidth) zeroes every
//!   collective and therefore lower-bounds every real fabric.
//! * [`autotune_fusion`] runs `analytic::fusion`'s bucket-size scan
//!   against the entry's channel on the chosen fabric and replays the
//!   winning bucket plan through the simulator, so fusion
//!   recommendations come from measurements, not the model
//!   (cf. the MPI-collective-in-DAG embedding of arXiv:1802.06949).

use super::fit::{CalibratedProfile, NetCalibration};
use super::replay::{self, resolve, Replayed};
use crate::analytic::{eqs, fusion};
use crate::campaign::grid::{CellResult, Interconnect, Scenario};
use crate::campaign::runner;
use crate::cluster::presets;
use crate::comm::alpha_beta::Link;
use crate::dag::builder::comm_topo;
use crate::frameworks::strategy::{self, Strategy};
use crate::models::perf::PerfModel;
use crate::sim::scheduler::SchedulerKind;
use crate::util::json::Json;
use crate::util::table::{f, Table};
use crate::util::units::{fmt_bytes, fmt_dur};
use std::collections::BTreeMap;

/// Version of the `BENCH_whatif.json` format; bump on any layout change.
pub const WHATIF_SCHEMA_VERSION: u64 = 1;

/// A hypothetical collective channel to price an entry's gradient
/// exchange on. Addressed by name so fabrics can ride in campaign cell
/// keys ([`Fabric::name`] / [`Fabric::parse`] round-trip).
#[derive(Clone, Debug, PartialEq)]
pub enum Fabric {
    /// The entry's own measured channel — what-if ≡ replay.
    Measured,
    /// Zero-latency, infinite-bandwidth: communication is free. Lower
    /// bound of every real fabric (the keystone property test).
    Ideal,
    /// A cluster preset's interconnect pair (intra + inter links) under
    /// the backend model, plus the entry's fitted framework overhead.
    Cluster(String),
    /// One of the paper's named inter-node fabrics swapped onto the
    /// *measured* cluster (`stock` models the measured fabric itself).
    Interconnect(Interconnect),
    /// An explicit α–β collective channel (plus fitted overhead).
    AlphaBeta { alpha_s: f64, bw_bps: f64 },
}

impl Fabric {
    /// Validated α–β constructor (the CLI's `--alpha/--beta` pair).
    pub fn alpha_beta(alpha_s: f64, bw_bps: f64) -> Result<Fabric, String> {
        if !alpha_s.is_finite() || alpha_s < 0.0 {
            return Err(format!("fabric α must be finite and ≥ 0, got {alpha_s}"));
        }
        if !bw_bps.is_finite() || bw_bps <= 0.0 {
            return Err(format!("fabric bandwidth must be finite and > 0, got {bw_bps}"));
        }
        Ok(Fabric::AlphaBeta { alpha_s, bw_bps })
    }

    /// Canonical name (cell keys, reports). `parse(name())` round-trips.
    pub fn name(&self) -> String {
        match self {
            Fabric::Measured => "measured".into(),
            Fabric::Ideal => "ideal".into(),
            Fabric::Cluster(c) => c.clone(),
            Fabric::Interconnect(i) => i.name().into(),
            Fabric::AlphaBeta { alpha_s, bw_bps } => format!("alpha{alpha_s}-bw{bw_bps}"),
        }
    }

    /// Resolve a fabric name: `measured`, `ideal`, an interconnect name
    /// (`stock`, `10gbe`, `100gb-ib`), a cluster preset, or the explicit
    /// `alpha<SECONDS>-bw<BYTES/S>` form.
    pub fn parse(name: &str) -> Result<Fabric, String> {
        match name {
            "measured" => Ok(Fabric::Measured),
            "ideal" => Ok(Fabric::Ideal),
            _ => {
                if let Some(rest) = name.strip_prefix("alpha") {
                    let (a, b) = rest.split_once("-bw").ok_or_else(|| {
                        format!("bad α–β fabric '{name}' (want alpha<SECONDS>-bw<BYTES/S>)")
                    })?;
                    let alpha_s: f64 =
                        a.parse().map_err(|e| format!("bad α in fabric '{name}': {e}"))?;
                    let bw_bps: f64 =
                        b.parse().map_err(|e| format!("bad bandwidth in fabric '{name}': {e}"))?;
                    Fabric::alpha_beta(alpha_s, bw_bps)
                } else if let Some(i) = Interconnect::by_name(name) {
                    Ok(Fabric::Interconnect(i))
                } else if let Some(c) = presets::by_name(name) {
                    Ok(Fabric::Cluster(c.name))
                } else {
                    Err(format!(
                        "unknown fabric '{name}' (try measured, ideal, stock, 10gbe, \
                         100gb-ib, a cluster preset, or alpha<S>-bw<B/S>)"
                    ))
                }
            }
        }
    }
}

/// The per-collective cost model of `entry`'s gradient exchange on a
/// fabric: seconds for one all-reduce of `bytes`. Single-rank entries
/// communicate for free on every fabric. Hypothetical fabrics price the
/// hardware with the backend model (or the explicit α–β line) and carry
/// the entry's *fitted framework overhead* on top — the software cost
/// measured on the real system follows the workload to the new fabric.
pub fn channel(
    entry: &NetCalibration,
    fabric: &Fabric,
    fw: &Strategy,
) -> Result<Box<dyn Fn(f64) -> f64>, String> {
    let (cluster, job) = resolve(entry)?;
    if job.ranks() <= 1 {
        return Ok(Box::new(|_| 0.0));
    }
    let overhead = entry.comm.map(|c| c.overhead_s).unwrap_or(0.0);
    match fabric {
        Fabric::Measured => {
            let cal = entry.calibrated_comm().ok_or_else(|| {
                format!("{}: no fitted comm channel to price collectives with", entry.key())
            })?;
            Ok(Box::new(move |bytes| cal.comm_time(bytes)))
        }
        Fabric::Ideal => Ok(Box::new(|_| 0.0)),
        Fabric::AlphaBeta { alpha_s, bw_bps } => {
            Fabric::alpha_beta(*alpha_s, *bw_bps)?; // reject NaN/negative pairs
            let link = Link::new(*alpha_s, *bw_bps);
            Ok(Box::new(move |bytes| overhead + link.xfer(bytes)))
        }
        Fabric::Cluster(name) => {
            let hypo = presets::by_name(name)
                .ok_or_else(|| format!("unknown cluster fabric '{name}'"))?;
            if job.nodes > hypo.nodes || job.gpus_per_node > hypo.gpus_per_node {
                return Err(format!(
                    "{}: {}x{} GPUs do not fit fabric cluster '{}' ({}x{})",
                    entry.key(),
                    job.nodes,
                    job.gpus_per_node,
                    hypo.name,
                    hypo.nodes,
                    hypo.gpus_per_node
                ));
            }
            let topo = comm_topo(&hypo, job.nodes, job.gpus_per_node);
            let mut base = fw.clone();
            base.calibrated_comm = None;
            Ok(Box::new(move |bytes| overhead + base.comm_time(&topo, bytes)))
        }
        Fabric::Interconnect(i) => {
            let mut swapped = cluster.clone();
            i.apply(&mut swapped);
            let topo = comm_topo(&swapped, job.nodes, job.gpus_per_node);
            let mut base = fw.clone();
            base.calibrated_comm = None;
            Ok(Box::new(move |bytes| overhead + base.comm_time(&topo, bytes)))
        }
    }
}

/// The substituted per-layer collective-cost vector for an entry on a
/// fabric, or `None` for the measured fabric (replay the raw
/// measurements — the bit-identity contract).
pub fn comm_override(
    entry: &NetCalibration,
    fabric: &Fabric,
    fw: &Strategy,
) -> Result<Option<Vec<f64>>, String> {
    if matches!(fabric, Fabric::Measured) {
        return Ok(None);
    }
    let ch = channel(entry, fabric, fw)?;
    Ok(Some(
        entry
            .layers
            .iter()
            .map(|l| if l.size_bytes > 0 { ch(l.size_bytes as f64) } else { 0.0 })
            .collect(),
    ))
}

/// One what-if prediction: an entry's measured compute simulated against
/// a fabric, with the measured-fabric replay as the baseline.
#[derive(Clone, Debug)]
pub struct Prediction {
    pub fabric: Fabric,
    pub scheduler: SchedulerKind,
    pub replayed: Replayed,
    /// Sum of the substituted per-layer collective costs, seconds.
    pub comm_total_s: f64,
    /// Replay on the measured fabric under the same scheduler.
    pub measured_iter_s: f64,
}

impl Prediction {
    /// >1: the hypothetical fabric is faster than the measured one.
    pub fn speedup_vs_measured(&self) -> f64 {
        self.measured_iter_s / self.replayed.iter_time_s
    }
}

/// Predict one entry on one fabric under one scheduling policy. The
/// measured baseline is recomputed per prediction — campaign cells must
/// stay pure functions of their scenario (deterministic, cacheable);
/// sweeps that already hold the baseline pass it via
/// [`predict_entry_with_baseline`] instead.
pub fn predict_entry(
    entry: &NetCalibration,
    fabric: &Fabric,
    kind: SchedulerKind,
    fw: &Strategy,
) -> Result<Prediction, String> {
    predict_entry_with_baseline(entry, fabric, kind, fw, None)
}

/// [`predict_entry`] with an optional precomputed measured-fabric
/// baseline (the replay of `entry` under `kind`), so batch sweeps don't
/// re-simulate the identical baseline once per fabric. The replay is
/// deterministic, so a supplied baseline is bit-identical to a
/// recomputed one.
pub fn predict_entry_with_baseline(
    entry: &NetCalibration,
    fabric: &Fabric,
    kind: SchedulerKind,
    fw: &Strategy,
    baseline: Option<f64>,
) -> Result<Prediction, String> {
    let comm = comm_override(entry, fabric, fw)?;
    let replayed = replay::replay_entry_with_comm(entry, kind, fw, comm.as_deref())?;
    let comm_total_s = match &comm {
        Some(v) => v.iter().sum(),
        None => entry.layers.iter().map(|l| l.comm_s).sum(),
    };
    let measured_iter_s = match (&comm, baseline) {
        (None, _) => replayed.iter_time_s,
        (Some(_), Some(b)) => b,
        (Some(_), None) => replay::replay_entry(entry, kind, fw)?.iter_time_s,
    };
    Ok(Prediction {
        fabric: fabric.clone(),
        scheduler: kind,
        replayed,
        comm_total_s,
        measured_iter_s,
    })
}

/// Result of autotuning the gradient-fusion bucket size against an
/// entry's channel on a fabric.
#[derive(Clone, Debug)]
pub struct FusionTune {
    /// Winning bucket-size cap, bytes.
    pub cap_bytes: f64,
    /// Buckets the winning cap partitions the gradient stream into.
    pub buckets: usize,
    /// Closed-form WFBP pipeline time at the winning cap (the scan
    /// objective, `analytic::fusion::pipeline_time`).
    pub scan_iter_s: f64,
    /// The winning bucket plan replayed through the DAG simulator
    /// (fused costs lowered via `fusion::fused_comm_vector`).
    pub replayed_iter_s: f64,
    /// Unfused (layer-wise) replay on the same fabric, for the gain.
    pub layerwise_iter_s: f64,
}

impl FusionTune {
    /// Replayed fusion gain over layer-wise exchange, percent.
    pub fn gain_pct(&self) -> f64 {
        100.0 * (self.layerwise_iter_s - self.replayed_iter_s) / self.layerwise_iter_s
    }
}

/// Run the bucket-size scan against the entry's channel on `fabric`
/// (for [`Fabric::Measured`], the profile's *fitted* α–β channel — the
/// ROADMAP's measurement-driven autotuning) and replay the winner.
/// Errors on single-rank entries, entries without gradient sizes, and
/// measured-fabric entries without a comm fit.
pub fn autotune_fusion(
    entry: &NetCalibration,
    fabric: &Fabric,
    fw: &Strategy,
) -> Result<FusionTune, String> {
    let (cluster, job) = resolve(entry)?;
    if job.ranks() <= 1 {
        return Err(format!("{}: single-rank job has nothing to fuse", entry.key()));
    }
    let bytes: Vec<f64> = entry.layers.iter().map(|l| l.size_bytes as f64).collect();
    if bytes.iter().sum::<f64>() <= 0.0 {
        return Err(format!("{}: trace records no gradient sizes", entry.key()));
    }
    let ch = channel(entry, fabric, fw)?;
    let pm = PerfModel::for_cluster(&cluster);
    let h2d = (job.batch_per_gpu as u64 * job.net.input_bytes) as f64 / cluster.h2d_bw;
    let dur = replay::durations_from(entry, &job, &pm, h2d);
    let comm: Vec<f64> = entry
        .layers
        .iter()
        .map(|l| if l.size_bytes > 0 { ch(l.size_bytes as f64) } else { 0.0 })
        .collect();
    let inputs = eqs::IterInputs {
        t_io: entry.t_io_s * cluster.io_sharing(job.nodes, job.gpus_per_node),
        t_h2d: h2d,
        fwd: dur.fwd.clone(),
        bwd: dur.bwd.clone(),
        comm: comm.clone(),
        t_u: dur.update,
    };
    let (_, best) = fusion::optimal_bucket_bytes_with(&inputs, &bytes, ch.as_ref());
    let bucketing = fusion::bucketing_by_cap(&bytes, best.cap_bytes);
    let fused = fusion::fused_comm_vector(&bucketing, &bytes, ch.as_ref());
    let replayed = replay::replay_entry_with_comm(entry, SchedulerKind::Fifo, fw, Some(&fused))?;
    let layerwise = replay::replay_entry_with_comm(entry, SchedulerKind::Fifo, fw, Some(&comm))?;
    Ok(FusionTune {
        cap_bytes: best.cap_bytes,
        buckets: best.buckets,
        scan_iter_s: best.iter_time,
        replayed_iter_s: replayed.iter_time_s,
        layerwise_iter_s: layerwise.iter_time_s,
    })
}

/// Campaign scenarios for a what-if sweep: one cell per profile entry ×
/// fabric × scheduler, tagged with the profile's content hash *and* the
/// fabric name, so cache entries stay content-addressed exactly like
/// `campaign --profile` cells.
pub fn scenarios(
    profile: &CalibratedProfile,
    fabrics: &[Fabric],
    kinds: &[SchedulerKind],
) -> Vec<Scenario> {
    let mut out = Vec::with_capacity(profile.entries.len() * fabrics.len() * kinds.len());
    for base in replay::scenarios(profile, kinds) {
        for fabric in fabrics {
            let mut s = base.clone();
            s.fabric = Some(fabric.name());
            out.push(s);
        }
    }
    out
}

/// A prediction lowered into the flat campaign metric map.
fn metrics_of(p: &Prediction) -> CellResult {
    let mut r = CellResult::new();
    r.set("iter_time_s", p.replayed.iter_time_s)
        .set("samples_per_s", p.replayed.samples_per_s)
        .set("makespan_s", p.replayed.makespan_s)
        .set("comm_total_s", p.comm_total_s)
        .set("measured_iter_s", p.measured_iter_s)
        .set("speedup_vs_measured", p.speedup_vs_measured());
    r
}

/// The per-cell measurement of what-if sweeps: predict the matching
/// entry on the cell's fabric under the cell's scheduler.
pub fn whatif_cell(profile: &CalibratedProfile, s: &Scenario) -> CellResult {
    let fw = strategy::by_name(&profile.framework).expect("profile validated before sweep");
    let entry = replay::entry_for(profile, s).expect("scenario was built from this profile");
    let fabric = Fabric::parse(s.fabric.as_deref().expect("whatif cells carry a fabric"))
        .expect("fabric validated before sweep");
    let p =
        predict_entry(entry, &fabric, s.scheduler, &fw).expect("fabric validated before sweep");
    metrics_of(&p)
}

/// Pre-sweep gate: the profile must be sweepable and every entry must be
/// pricable on every requested fabric, so a bad fabric fails with a
/// message before workers spawn. The measured fabric is exempt from the
/// channel check — prediction on it replays raw measurements and needs
/// no fitted channel.
pub fn validate_whatif(profile: &CalibratedProfile, fabrics: &[Fabric]) -> Result<(), String> {
    replay::validate_profile(profile)?;
    if fabrics.is_empty() {
        return Err("no fabrics to sweep".into());
    }
    let fw = strategy::by_name(&profile.framework).expect("validate_profile checked the name");
    for entry in &profile.entries {
        for fabric in fabrics {
            if matches!(fabric, Fabric::Measured) {
                continue;
            }
            channel(entry, fabric, &fw)
                .map_err(|e| format!("{} on fabric '{}': {e}", entry.key(), fabric.name()))?;
        }
    }
    Ok(())
}

/// One report row: an entry × fabric × scheduler prediction, with the
/// optional fusion autotune attached (shared across the schedulers of
/// the same entry × fabric).
#[derive(Clone, Debug)]
pub struct WhatIfRow {
    pub net: String,
    pub cluster: String,
    pub gpus: usize,
    pub batch: usize,
    pub fabric: String,
    pub scheduler: SchedulerKind,
    pub iter_time_s: f64,
    pub samples_per_s: f64,
    pub comm_total_s: f64,
    pub measured_iter_s: f64,
    pub speedup_vs_measured: f64,
    pub fusion: Option<FusionTune>,
}

/// Sweep a profile across fabrics × schedulers on `jobs` workers and
/// shape the cells into report rows. With `autotune`, each entry ×
/// fabric additionally carries the fusion autotune (entries that cannot
/// fuse — single rank, no gradient sizes, measured fabric without a comm
/// fit — get `fusion: None` instead of failing the sweep).
pub fn rows(
    profile: &CalibratedProfile,
    fabrics: &[Fabric],
    kinds: &[SchedulerKind],
    autotune: bool,
    jobs: usize,
) -> Result<Vec<WhatIfRow>, String> {
    validate_whatif(profile, fabrics)?;
    if kinds.is_empty() {
        return Err("no schedulers to sweep".into());
    }
    let fw = strategy::by_name(&profile.framework).expect("validated");

    // Measured baselines once per entry × scheduler (the replay is
    // deterministic, so injecting them into every prediction is
    // bit-identical to the cells recomputing them per fabric). Only
    // needed when a hypothetical fabric is in the sweep — measured
    // cells are their own baseline.
    let mut baselines: BTreeMap<(String, &str), f64> = BTreeMap::new();
    if fabrics.iter().any(|f| !matches!(f, Fabric::Measured)) {
        for entry in &profile.entries {
            for &kind in kinds {
                let base = replay::replay_entry(entry, kind, &fw)
                    .map_err(|e| format!("{}: {e}", entry.key()))?;
                baselines.insert((entry.key(), kind.name()), base.iter_time_s);
            }
        }
    }

    let cells = scenarios(profile, fabrics, kinds);
    let outcome = runner::run_with(&cells, jobs, None, |s| {
        let entry = replay::entry_for(profile, s).expect("scenario was built from this profile");
        let fabric = Fabric::parse(s.fabric.as_deref().expect("whatif cells carry a fabric"))
            .expect("fabric validated before sweep");
        let base = baselines.get(&(entry.key(), s.scheduler.name())).copied();
        let p = predict_entry_with_baseline(entry, &fabric, s.scheduler, &fw, base)
            .expect("fabric validated before sweep");
        metrics_of(&p)
    });

    // Fusion autotunes are scheduler-independent: one per entry ×
    // fabric, fanned through the same worker pool (they are the
    // heaviest stage — a bucket-cap scan plus two replays each).
    let mut tunes: BTreeMap<(String, String), FusionTune> = BTreeMap::new();
    if autotune {
        let tune_cells = scenarios(profile, fabrics, &[SchedulerKind::Fifo]);
        let tuned = runner::run_with(&tune_cells, jobs, None, |s| {
            let entry =
                replay::entry_for(profile, s).expect("scenario was built from this profile");
            let fabric = Fabric::parse(s.fabric.as_deref().expect("whatif cells carry a fabric"))
                .expect("fabric validated before sweep");
            let mut r = CellResult::new();
            // Entries that cannot fuse (single rank, no gradient sizes,
            // measured fabric without a comm fit) yield an empty cell.
            if let Ok(t) = autotune_fusion(entry, &fabric, &fw) {
                r.set("cap_bytes", t.cap_bytes)
                    .set("buckets", t.buckets as f64)
                    .set("scan_iter_s", t.scan_iter_s)
                    .set("replayed_iter_s", t.replayed_iter_s)
                    .set("layerwise_iter_s", t.layerwise_iter_s);
            }
            r
        });
        for (s, r) in &tuned.cells {
            let entry = replay::entry_for(profile, s).expect("tune scenario from this profile");
            let fabric_name = s.fabric.clone().expect("whatif cells carry a fabric");
            if let Some(cap_bytes) = r.get("cap_bytes") {
                tunes.insert(
                    (entry.key(), fabric_name),
                    FusionTune {
                        cap_bytes,
                        buckets: r.get("buckets").expect("tune cell metric") as usize,
                        scan_iter_s: r.get("scan_iter_s").expect("tune cell metric"),
                        replayed_iter_s: r.get("replayed_iter_s").expect("tune cell metric"),
                        layerwise_iter_s: r.get("layerwise_iter_s").expect("tune cell metric"),
                    },
                );
            }
        }
    }

    let mut out = Vec::with_capacity(outcome.cells.len());
    for (s, r) in &outcome.cells {
        let entry = replay::entry_for(profile, s).expect("scenario was built from this profile");
        let fabric_name = s.fabric.clone().expect("whatif cells carry a fabric");
        let metric = |k: &str| r.get(k).expect("whatif cell metric");
        out.push(WhatIfRow {
            net: s.net.clone(),
            cluster: s.cluster.clone(),
            gpus: entry.gpus,
            batch: entry.batch,
            fabric: fabric_name.clone(),
            scheduler: s.scheduler,
            iter_time_s: metric("iter_time_s"),
            samples_per_s: metric("samples_per_s"),
            comm_total_s: metric("comm_total_s"),
            measured_iter_s: metric("measured_iter_s"),
            speedup_vs_measured: metric("speedup_vs_measured"),
            fusion: tunes.get(&(entry.key(), fabric_name)).cloned(),
        });
    }
    Ok(out)
}

/// Render the human table.
pub fn render(rows: &[WhatIfRow]) -> String {
    let mut t = Table::new(&[
        "net",
        "cluster",
        "gpus",
        "fabric",
        "scheduler",
        "measured",
        "predicted",
        "speedup",
        "comm",
        "fusion cap",
        "fusion gain",
    ]);
    for r in rows {
        let (cap, gain) = match &r.fusion {
            Some(tune) => (fmt_bytes(tune.cap_bytes), format!("{}%", f(tune.gain_pct(), 1))),
            None => ("-".into(), "-".into()),
        };
        t.row(&[
            r.net.clone(),
            r.cluster.clone(),
            r.gpus.to_string(),
            r.fabric.clone(),
            r.scheduler.name().to_string(),
            fmt_dur(r.measured_iter_s),
            fmt_dur(r.iter_time_s),
            format!("{}x", f(r.speedup_vs_measured, 2)),
            fmt_dur(r.comm_total_s),
            cap,
            gain,
        ]);
    }
    t.render()
}

/// Serialize the report (schema v`WHATIF_SCHEMA_VERSION`).
pub fn report_to_json(rows: &[WhatIfRow], framework: &str, profile_tag: &str) -> Json {
    let row_json: Vec<Json> = rows
        .iter()
        .map(|r| {
            let fusion = match &r.fusion {
                None => Json::Null,
                Some(t) => Json::obj(vec![
                    ("cap_bytes", Json::num(t.cap_bytes)),
                    ("buckets", Json::num(t.buckets as f64)),
                    ("scan_iter_s", Json::num(t.scan_iter_s)),
                    ("replayed_iter_s", Json::num(t.replayed_iter_s)),
                    ("layerwise_iter_s", Json::num(t.layerwise_iter_s)),
                ]),
            };
            Json::obj(vec![
                ("net", Json::str(r.net.clone())),
                ("cluster", Json::str(r.cluster.clone())),
                ("gpus", Json::num(r.gpus as f64)),
                ("batch", Json::num(r.batch as f64)),
                ("fabric", Json::str(r.fabric.clone())),
                ("scheduler", Json::str(r.scheduler.name())),
                ("iter_time_s", Json::num(r.iter_time_s)),
                ("samples_per_s", Json::num(r.samples_per_s)),
                ("comm_total_s", Json::num(r.comm_total_s)),
                ("measured_iter_s", Json::num(r.measured_iter_s)),
                ("speedup_vs_measured", Json::num(r.speedup_vs_measured)),
                ("fusion", fusion),
            ])
        })
        .collect();
    Json::obj(vec![
        ("schema_version", Json::num(WHATIF_SCHEMA_VERSION as f64)),
        ("bench", Json::str("whatif")),
        ("framework", Json::str(framework)),
        ("profile", Json::str(profile_tag)),
        ("rows", Json::Arr(row_json)),
    ])
}

/// Validate a `BENCH_whatif.json` against schema v1. Returns the row
/// count.
pub fn validate_report(report: &Json) -> Result<usize, String> {
    let version = report
        .get("schema_version")
        .and_then(|v| v.as_f64())
        .ok_or("missing schema_version")?;
    if version != WHATIF_SCHEMA_VERSION as f64 {
        return Err(format!(
            "schema_version {version} != supported {WHATIF_SCHEMA_VERSION}"
        ));
    }
    if report.get("bench").and_then(|v| v.as_str()) != Some("whatif") {
        return Err("bench field must be \"whatif\"".into());
    }
    for field in ["framework", "profile"] {
        report
            .get(field)
            .and_then(|v| v.as_str())
            .ok_or_else(|| format!("missing string field '{field}'"))?;
    }
    let rows = report
        .get("rows")
        .and_then(|v| v.as_arr())
        .ok_or("missing rows array")?;
    if rows.is_empty() {
        return Err("rows array is empty".into());
    }
    let req_num = |row: &Json, field: &str, at: &str| -> Result<f64, String> {
        let v = row
            .get(field)
            .and_then(|v| v.as_f64())
            .ok_or_else(|| format!("{at}: missing numeric field '{field}'"))?;
        if !v.is_finite() || v < 0.0 {
            return Err(format!("{at}: field '{field}' must be finite and ≥ 0"));
        }
        Ok(v)
    };
    for (i, row) in rows.iter().enumerate() {
        let at = format!("rows[{i}]");
        for field in ["net", "cluster", "fabric", "scheduler"] {
            row.get(field)
                .and_then(|v| v.as_str())
                .ok_or_else(|| format!("{at}: missing string field '{field}'"))?;
        }
        for field in [
            "gpus",
            "batch",
            "iter_time_s",
            "samples_per_s",
            "comm_total_s",
            "measured_iter_s",
            "speedup_vs_measured",
        ] {
            req_num(row, field, &at)?;
        }
        // comm_total_s may legitimately be 0 (ideal fabric, single GPU);
        // everything else must be positive.
        for field in [
            "gpus",
            "iter_time_s",
            "samples_per_s",
            "measured_iter_s",
            "speedup_vs_measured",
        ] {
            if row.get(field).and_then(|v| v.as_f64()) == Some(0.0) {
                return Err(format!("{at}: field '{field}' must be positive"));
            }
        }
        match row.get("fusion") {
            None | Some(Json::Null) => {}
            Some(fusion) => {
                for field in [
                    "cap_bytes",
                    "buckets",
                    "scan_iter_s",
                    "replayed_iter_s",
                    "layerwise_iter_s",
                ] {
                    let v = req_num(fusion, field, &format!("{at}.fusion"))?;
                    if v <= 0.0 {
                        return Err(format!("{at}.fusion: field '{field}' must be positive"));
                    }
                }
            }
        }
    }
    Ok(rows.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::fit::calibrate_one;
    use crate::dag::builder::JobSpec;
    use crate::frameworks::strategy as fws;
    use crate::models::zoo;
    use crate::trace::synth::synth_trace;
    use crate::util::json;

    fn entry_of(
        net: crate::models::layer::NetSpec,
        cluster: &crate::cluster::topology::ClusterSpec,
        nodes: usize,
        gpn: usize,
    ) -> NetCalibration {
        let job = JobSpec {
            batch_per_gpu: net.default_batch,
            net,
            nodes,
            gpus_per_node: gpn,
            iterations: 1,
        };
        let t = synth_trace(cluster, &job, &fws::caffe_mpi(), 10, 23);
        calibrate_one(&t, &fws::caffe_mpi()).unwrap()
    }

    fn profile_for(cluster: &crate::cluster::topology::ClusterSpec) -> CalibratedProfile {
        CalibratedProfile {
            framework: "caffe-mpi".into(),
            entries: vec![
                entry_of(zoo::alexnet(), cluster, 2, 4),
                entry_of(zoo::resnet50(), cluster, 4, 4),
            ],
        }
    }

    #[test]
    fn fabric_names_round_trip() {
        let fabrics = [
            Fabric::Measured,
            Fabric::Ideal,
            Fabric::Cluster("v100-nvlink-ib".into()),
            Fabric::Interconnect(Interconnect::TenGbE),
            Fabric::Interconnect(Interconnect::Stock),
            Fabric::alpha_beta(4e-5, 1.25e9).unwrap(),
        ];
        for f in &fabrics {
            let back = Fabric::parse(&f.name()).unwrap_or_else(|e| panic!("{}: {e}", f.name()));
            assert_eq!(&back, f, "{}", f.name());
        }
        assert!(Fabric::parse("warpdrive").is_err());
        assert!(Fabric::parse("alpha1e-5").is_err(), "missing -bw part");
        assert!(Fabric::alpha_beta(-1.0, 1e9).is_err());
        assert!(Fabric::alpha_beta(0.0, 0.0).is_err());
        // Short cluster aliases canonicalize to the full preset name.
        assert_eq!(Fabric::parse("v100").unwrap().name(), "v100-nvlink-ib");
    }

    /// The bit-identity contract: the measured fabric takes the exact
    /// replay code path.
    #[test]
    fn measured_fabric_is_bit_identical_to_replay() {
        let cluster = crate::cluster::presets::k80_cluster();
        let entry = entry_of(zoo::alexnet(), &cluster, 2, 4);
        let fw = fws::caffe_mpi();
        for kind in [SchedulerKind::Fifo, SchedulerKind::Priority] {
            let p = predict_entry(&entry, &Fabric::Measured, kind, &fw).unwrap();
            let r = replay::replay_entry(&entry, kind, &fw).unwrap();
            assert_eq!(p.replayed.iter_time_s.to_bits(), r.iter_time_s.to_bits());
            assert_eq!(p.replayed.makespan_s.to_bits(), r.makespan_s.to_bits());
            assert_eq!(p.speedup_vs_measured(), 1.0);
        }
    }

    #[test]
    fn ideal_fabric_lower_bounds_real_fabrics() {
        let cluster = crate::cluster::presets::v100_cluster();
        let entry = entry_of(zoo::resnet50(), &cluster, 4, 4);
        let fw = fws::caffe_mpi();
        let ideal = predict_entry(&entry, &Fabric::Ideal, SchedulerKind::Fifo, &fw).unwrap();
        assert_eq!(ideal.comm_total_s, 0.0);
        for fabric in [
            Fabric::Measured,
            Fabric::Interconnect(Interconnect::TenGbE),
            Fabric::Interconnect(Interconnect::Ib100),
            Fabric::Cluster("k80-pcie-10gbe".into()),
            Fabric::alpha_beta(1e-4, 1e9).unwrap(),
        ] {
            let p = predict_entry(&entry, &fabric, SchedulerKind::Fifo, &fw).unwrap();
            assert!(
                ideal.replayed.iter_time_s <= p.replayed.iter_time_s + 1e-12,
                "ideal {} > {} on {}",
                ideal.replayed.iter_time_s,
                p.replayed.iter_time_s,
                fabric.name()
            );
        }
    }

    /// Swapping the 10 GbE cluster's measured workload onto the 100 Gb
    /// IB fabric must speed up the comm-bound job — the paper's central
    /// what-if, now answered from measurements.
    #[test]
    fn faster_fabric_speeds_up_comm_bound_entry() {
        let cluster = crate::cluster::presets::k80_cluster();
        let entry = entry_of(zoo::resnet50(), &cluster, 4, 4);
        let fw = fws::caffe_mpi();
        let fabric = Fabric::Interconnect(Interconnect::Ib100);
        let ib = predict_entry(&entry, &fabric, SchedulerKind::Fifo, &fw).unwrap();
        assert!(
            ib.speedup_vs_measured() > 1.0,
            "IB should beat measured 10GbE: {}x",
            ib.speedup_vs_measured()
        );
        assert!(ib.comm_total_s > 0.0);
    }

    #[test]
    fn autotune_fusion_beats_layerwise_on_comm_bound_entry() {
        let cluster = crate::cluster::presets::v100_cluster();
        let entry = entry_of(zoo::resnet50(), &cluster, 4, 4);
        let fw = fws::caffe_mpi();
        let tune = autotune_fusion(&entry, &Fabric::Measured, &fw).unwrap();
        assert!(tune.buckets > 1, "optimum should fuse but not into one bucket");
        assert!(tune.cap_bytes >= 64.0 * 1024.0);
        assert!(
            tune.replayed_iter_s < tune.layerwise_iter_s,
            "fused replay {} should beat layer-wise {}",
            tune.replayed_iter_s,
            tune.layerwise_iter_s
        );
        assert!(tune.gain_pct() > 0.0);
        // Single-rank entries cannot fuse.
        let solo = entry_of(zoo::googlenet(), &cluster, 1, 1);
        assert!(autotune_fusion(&solo, &Fabric::Measured, &fw).is_err());
    }

    #[test]
    fn scenarios_cross_entries_fabrics_schedulers() {
        let cluster = crate::cluster::presets::k80_cluster();
        let profile = profile_for(&cluster);
        let fabrics = [Fabric::Measured, Fabric::Ideal];
        let kinds = [SchedulerKind::Fifo, SchedulerKind::Priority];
        validate_whatif(&profile, &fabrics).unwrap();
        let cells = scenarios(&profile, &fabrics, &kinds);
        assert_eq!(cells.len(), 2 * 2 * 2);
        let mut keys: Vec<String> = cells.iter().map(|s| s.key()).collect();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), cells.len(), "fabric axis must keep keys distinct");
        assert!(keys.iter().any(|k| k.contains("fabric=ideal")));
        assert!(keys.iter().all(|k| k.contains("profile=caffe-mpi#")));
        let outcome = runner::run_with(&cells, 2, None, |s| whatif_cell(&profile, s));
        for (s, r) in &outcome.cells {
            assert!(r.get("iter_time_s").unwrap() > 0.0, "{}", s.key());
            assert!(r.get("speedup_vs_measured").unwrap() > 0.0);
            if s.fabric.as_deref() == Some("ideal") {
                assert_eq!(r.get("comm_total_s"), Some(0.0));
            }
        }
    }

    #[test]
    fn validate_whatif_gates_bad_fabrics() {
        let cluster = crate::cluster::presets::k80_cluster();
        let profile = profile_for(&cluster);
        assert!(validate_whatif(&profile, &[]).is_err());
        // localhost has 1 node x 4 workers: the 4-node entry cannot fit.
        let err = validate_whatif(&profile, &[Fabric::Cluster("localhost-shm".into())])
            .unwrap_err();
        assert!(err.contains("do not fit"), "{err}");
        // The measured fabric is exempt from channel checks.
        validate_whatif(&profile, &[Fabric::Measured, Fabric::Ideal]).unwrap();
    }

    #[test]
    fn report_roundtrips_and_validator_rejects_tampering() {
        let cluster = crate::cluster::presets::k80_cluster();
        let profile = profile_for(&cluster);
        let fabrics = [Fabric::Measured, Fabric::Interconnect(Interconnect::Ib100)];
        let rows = rows(&profile, &fabrics, &[SchedulerKind::Fifo], true, 2).unwrap();
        assert_eq!(rows.len(), 2 * 2);
        assert!(
            rows.iter().any(|r| r.fusion.is_some()),
            "multi-rank entries should autotune"
        );
        let table = render(&rows);
        assert!(table.contains("ib") || table.contains("100gb-ib"));

        let good = report_to_json(&rows, &profile.framework, &profile.tag());
        let text = good.to_string();
        let back = json::parse(&text).unwrap();
        assert_eq!(validate_report(&back).unwrap(), rows.len());
        let check = |s: &str| validate_report(&json::parse(s).unwrap());
        assert!(check(&text.replace("\"schema_version\":1", "\"schema_version\":3")).is_err());
        assert!(check(&text.replace("\"bench\":\"whatif\"", "\"bench\":\"other\"")).is_err());
        assert!(check(&text.replace("\"rows\":[", "\"cells\":[")).is_err());
        assert!(check("{\"schema_version\":1,\"bench\":\"whatif\"}").is_err());
    }
}
