//! The what-if engine: measured compute on hypothetical fabrics.
//!
//! The paper's payoff is predictive — its PCIe/NVLink/10GbE/InfiniBand
//! study asks "what would this workload cost on that interconnect". PR 3
//! closed the trace → [`CalibratedProfile`] → replay loop, but replay
//! only reproduces the measured hardware. This module completes the
//! other half: keep an entry's *measured* per-layer compute costs and
//! fitted framework overhead, substitute a **hypothetical** collective
//! channel (a cluster preset, a named inter-node fabric, an explicit
//! α–β pair, or the degenerate ideal channel), rebuild the S-SGD DAG via
//! `builder::build_with_cached` (every cell of a fabric sweep re-stamps
//! the same cached [`crate::dag::builder::DagTemplate`] — only durations
//! change) and simulate it under any scheduler — the α–β comm analysis
//! shared with arXiv:1711.05979 applied forward instead of backward.
//!
//! Contracts the tests pin:
//!
//! * [`Fabric::Measured`] passes **no** comm substitution, so a what-if
//!   prediction on the measured fabric is the same code path as
//!   [`replay::replay_entry`] — bit-identical by construction.
//! * [`Fabric::Ideal`] (zero-α, infinite-bandwidth) zeroes every
//!   collective and therefore lower-bounds every real fabric.
//! * [`autotune_fusion`] runs `analytic::fusion`'s bucket-size scan
//!   against the entry's channel on the chosen fabric and replays the
//!   winning bucket plan through the simulator, so fusion
//!   recommendations come from measurements, not the model
//!   (cf. the MPI-collective-in-DAG embedding of arXiv:1802.06949).

use super::fit::{CalibratedProfile, CommFit, NetCalibration};
use super::replay::{self, resolve, resolve_at, Replayed};
use crate::analytic::{eqs, fusion};
use crate::campaign::grid::{CellResult, Interconnect, Scenario};
use crate::campaign::runner;
use crate::cluster::presets;
use crate::cluster::topology::ClusterSpec;
use crate::comm::alpha_beta::Link;
use crate::comm::network::{self, LinkUse, RoutedCollective, RoutedSpec};
use crate::dag::builder::{comm_topo, JobSpec};
use crate::frameworks::strategy::{self, Backend, CalibratedComm, Strategy};
use crate::models::perf::PerfModel;
use crate::obs::breakdown::{self, Bottleneck};
use crate::sim::lower_bound;
use crate::sim::scheduler::SchedulerKind;
use crate::util::json::Json;
use crate::util::table::{f, Table};
use crate::util::units::{fmt_bytes, fmt_dur};
use std::collections::BTreeMap;

/// Version of the `BENCH_whatif.json` format; bump on any layout change.
/// v2 added the scale-out axis (`topology` + `pred_gpus` per row).
/// v3 added the optional `lower_bound_s` / `gap_to_bound` columns and
/// the `portfolio_winner` tag on portfolio rows; v2 reports still
/// validate ([`validate_report`] accepts both).
pub const WHATIF_SCHEMA_VERSION: u64 = 3;

/// Version of the report's `explain` section (the obs breakdown per
/// row); independent of the row schema so explain consumers can evolve
/// without re-versioning the whole report.
pub const EXPLAIN_SCHEMA_VERSION: u64 = 1;

/// Rank ceiling for hypothetical topologies: generous headroom over the
/// paper's testbeds while keeping a typo'd `1000x1000` from building a
/// multi-gigabyte DAG inside a sweep worker.
pub const MAX_TOPOLOGY_RANKS: usize = 4096;

/// A hypothetical rank layout to rescale a measured entry onto — the
/// scale-out axis of the what-if engine (`whatif --nodes/--gpus`, the
/// campaign `topology` axis). Addressed by name (`"<nodes>x<gpus>"`) so
/// topologies ride in campaign cell keys exactly like fabrics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Topology {
    pub nodes: usize,
    pub gpus_per_node: usize,
}

impl Topology {
    /// Validated constructor: both counts ≥ 1, total ranks capped.
    pub fn new(nodes: usize, gpus_per_node: usize) -> Result<Topology, String> {
        if nodes == 0 || gpus_per_node == 0 {
            return Err(format!(
                "topology {nodes}x{gpus_per_node} has no GPUs (both counts must be ≥ 1)"
            ));
        }
        if nodes.saturating_mul(gpus_per_node) > MAX_TOPOLOGY_RANKS {
            return Err(format!(
                "topology {nodes}x{gpus_per_node} exceeds {MAX_TOPOLOGY_RANKS} ranks"
            ));
        }
        Ok(Topology {
            nodes,
            gpus_per_node,
        })
    }

    pub fn ranks(&self) -> usize {
        self.nodes * self.gpus_per_node
    }

    /// Canonical name (cell keys, reports). `parse(name())` round-trips.
    pub fn name(&self) -> String {
        format!("{}x{}", self.nodes, self.gpus_per_node)
    }

    /// Parse the `<nodes>x<gpus_per_node>` form.
    pub fn parse(s: &str) -> Result<Topology, String> {
        let (n, g) = s
            .split_once('x')
            .ok_or_else(|| format!("bad topology '{s}' (want <nodes>x<gpus_per_node>)"))?;
        let nodes: usize = n
            .parse()
            .map_err(|e| format!("bad node count in topology '{s}': {e}"))?;
        let gpus_per_node: usize = g
            .parse()
            .map_err(|e| format!("bad GPU count in topology '{s}': {e}"))?;
        Topology::new(nodes, gpus_per_node)
    }
}

/// The rank layout an entry was measured at (its [`replay::resolve`]d
/// node split).
pub fn measured_topology(entry: &NetCalibration) -> Result<Topology, String> {
    let (_, job) = resolve(entry)?;
    Topology::new(job.nodes, job.gpus_per_node)
}

/// Collapse an explicit topology equal to the entry's measured layout
/// onto `None`, so "rescale to the scale you measured at" takes the
/// exact measured-layout code path — the bit-identity keystone — and
/// every caller (validation, cells, autotune) agrees on which path runs.
fn effective_topology(
    entry: &NetCalibration,
    topo: Option<Topology>,
) -> Result<Option<Topology>, String> {
    match topo {
        None => Ok(None),
        Some(t) => {
            if t == measured_topology(entry)? {
                Ok(None)
            } else {
                Ok(Some(t))
            }
        }
    }
}

/// Affine `(intercept, slope)` view of the backend collective model at a
/// rank layout. Every backend (ring / tree / hierarchical / parameter
/// server / gRPC) prices one collective as `A(topology) + S · B(topology)`
/// at a fixed participant count, so two probes recover the structural
/// latency and bandwidth factors exactly. These factors are what scale a
/// *fitted* α–β channel to a different participant count.
fn backend_affine(
    cluster: &ClusterSpec,
    nodes: usize,
    gpus_per_node: usize,
    base: &Strategy,
) -> (f64, f64) {
    let topo = comm_topo(cluster, nodes, gpus_per_node);
    const S1: f64 = 1.0;
    const S2: f64 = 64.0 * 1024.0 * 1024.0;
    let t1 = base.comm_time(&topo, S1);
    let t2 = base.comm_time(&topo, S2);
    let slope = (t2 - t1) / (S2 - S1);
    (t1 - slope * S1, slope)
}

/// Re-price a fitted α–β channel at a different participant count: the
/// hardware-attributable latency scales with the backend model's
/// latency-structure ratio, the inverse bandwidth with its bandwidth-
/// structure ratio, and the fitted *framework overhead* — software cost
/// per collective, not a function of scale — rides along unchanged.
/// This is the arXiv:1711.05979 workflow run forward: fit at one scale,
/// extrapolate through the collective's closed form to another.
fn scaled_comm_fit(
    fit: CommFit,
    cluster: &ClusterSpec,
    from: Topology,
    to: Topology,
    fw: &Strategy,
) -> Result<CommFit, String> {
    let mut base = fw.clone();
    base.calibrated_comm = None;
    let (a_from, b_from) = backend_affine(cluster, from.nodes, from.gpus_per_node, &base);
    let (a_to, b_to) = backend_affine(cluster, to.nodes, to.gpus_per_node, &base);
    let from_ok = a_from.is_finite() && a_from > 0.0 && b_from.is_finite() && b_from > 0.0;
    if !from_ok {
        return Err(format!(
            "backend model is degenerate at the measured layout {} (cannot rescale)",
            from.name()
        ));
    }
    let (alpha_factor, slope_factor) = (a_to / a_from, b_to / b_from);
    if !alpha_factor.is_finite() || !slope_factor.is_finite() || slope_factor <= 0.0 {
        return Err(format!(
            "backend model is degenerate at the target layout {} (cannot rescale)",
            to.name()
        ));
    }
    let link = Link::new(fit.alpha_s, fit.bw_bps).rescaled(alpha_factor, slope_factor);
    Ok(CommFit {
        alpha_s: link.alpha,
        bw_bps: link.bw,
        overhead_s: fit.overhead_s,
        samples: fit.samples,
    })
}

/// Synthesize the entry a profile *would* contain had the same per-GPU
/// job been measured on `topo` — the tentpole of the scale-out what-if:
///
/// * per-layer forward/backward costs and the data-layer fetch are the
///   measured per-GPU minibatch numbers, carried over verbatim (weak
///   scaling keeps the per-GPU workload fixed; I/O contention and Eq. 6's
///   `io_sharing` re-emerge from the DAG's shared resources at the new
///   node count);
/// * the fitted per-layer efficiencies and framework overhead are kept;
/// * every collective is re-priced through the fitted α–β channel scaled
///   to the new participant count ([`scaled_comm_fit`]), and the scaled
///   fit is installed on the entry so downstream pricing (fusion
///   autotunes, the measured fabric) answers at the new scale.
///
/// Rescaling to the measured layout returns the entry unchanged — the
/// bit-identity contract. A multi-rank target needs a fitted channel;
/// a single-rank target drops communication entirely.
pub fn rescale_entry(
    entry: &NetCalibration,
    topo: Topology,
    fw: &Strategy,
) -> Result<NetCalibration, String> {
    let (cluster, job) = resolve(entry)?;
    let from = Topology::new(job.nodes, job.gpus_per_node)?;
    if from == topo {
        return Ok(entry.clone());
    }
    let mut out = entry.clone();
    out.gpus = topo.ranks();
    if topo.ranks() <= 1 {
        out.comm = None;
        for l in &mut out.layers {
            l.comm_s = 0.0;
        }
        return Ok(out);
    }
    let fit = entry.comm.ok_or_else(|| {
        format!(
            "{}: no fitted comm channel to re-price collectives at {}",
            entry.key(),
            topo.name()
        )
    })?;
    let scaled = scaled_comm_fit(fit, &cluster, from, topo, fw)?;
    let channel = CalibratedComm {
        link: Link::new(scaled.alpha_s, scaled.bw_bps),
        overhead_s: scaled.overhead_s,
    };
    out.comm = Some(scaled);
    for l in &mut out.layers {
        l.comm_s = if l.size_bytes > 0 {
            channel.comm_time(l.size_bytes as f64)
        } else {
            0.0
        };
    }
    Ok(out)
}

/// The single resolution step every topology-aware entry point shares:
/// collapse the target onto the measured layout when they coincide
/// ([`effective_topology`], the bit-identity contract), rescale
/// otherwise, and hand back the collapsed target, the synthesized entry
/// (`None` when no real rescale happened — callers fall back to the
/// original) and the replay-layout override.
fn rescaled_for(
    entry: &NetCalibration,
    topo: Option<Topology>,
    fw: &Strategy,
) -> Result<(Option<Topology>, Option<NetCalibration>, Option<(usize, usize)>), String> {
    match effective_topology(entry, topo)? {
        None => Ok((None, None, None)),
        Some(t) => Ok((
            Some(t),
            Some(rescale_entry(entry, t, fw)?),
            Some((t.nodes, t.gpus_per_node)),
        )),
    }
}

/// A hypothetical collective channel to price an entry's gradient
/// exchange on. Addressed by name so fabrics can ride in campaign cell
/// keys ([`Fabric::name`] / [`Fabric::parse`] round-trip).
#[derive(Clone, Debug, PartialEq)]
pub enum Fabric {
    /// The entry's own measured channel — what-if ≡ replay.
    Measured,
    /// Zero-latency, infinite-bandwidth: communication is free. Lower
    /// bound of every real fabric (the keystone property test).
    Ideal,
    /// A cluster preset's interconnect pair (intra + inter links) under
    /// the backend model, plus the entry's fitted framework overhead.
    Cluster(String),
    /// One of the paper's named inter-node fabrics swapped onto the
    /// *measured* cluster (`stock` models the measured fabric itself).
    Interconnect(Interconnect),
    /// An explicit α–β collective channel (plus fitted overhead).
    AlphaBeta { alpha_s: f64, bw_bps: f64 },
    /// A routed, contention-aware fabric graph built from a cluster
    /// preset's links ([`crate::comm::network`]): GPUs under node
    /// switches, NICs under a spine with a finite backplane, collectives
    /// lowered to per-link flow sets under max-min sharing. The
    /// `dedicated` variant prices every flow on a private link and is
    /// bit-identical to the flat backend model — the keystone contract.
    Routed(RoutedSpec),
}

impl Fabric {
    /// Validated α–β constructor (the CLI's `--alpha/--beta` pair).
    pub fn alpha_beta(alpha_s: f64, bw_bps: f64) -> Result<Fabric, String> {
        if !alpha_s.is_finite() || alpha_s < 0.0 {
            return Err(format!("fabric α must be finite and ≥ 0, got {alpha_s}"));
        }
        if !bw_bps.is_finite() || bw_bps <= 0.0 {
            return Err(format!("fabric bandwidth must be finite and > 0, got {bw_bps}"));
        }
        Ok(Fabric::AlphaBeta { alpha_s, bw_bps })
    }

    /// Canonical name (cell keys, reports). `parse(name())` round-trips.
    pub fn name(&self) -> String {
        match self {
            Fabric::Measured => "measured".into(),
            Fabric::Ideal => "ideal".into(),
            Fabric::Cluster(c) => c.clone(),
            Fabric::Interconnect(i) => i.name().into(),
            Fabric::AlphaBeta { alpha_s, bw_bps } => format!("alpha{alpha_s}-bw{bw_bps}"),
            Fabric::Routed(spec) => spec.name(),
        }
    }

    /// Resolve a fabric name: `measured`, `ideal`, an interconnect name
    /// (`stock`, `10gbe`, `100gb-ib`), a cluster preset, the explicit
    /// `alpha<SECONDS>-bw<BYTES/S>` form, or a routed graph
    /// (`routed:<cluster>[:dedicated|:spine=<k>]`).
    pub fn parse(name: &str) -> Result<Fabric, String> {
        match name {
            "measured" => Ok(Fabric::Measured),
            "ideal" => Ok(Fabric::Ideal),
            _ => {
                if name.starts_with("routed:") {
                    Ok(Fabric::Routed(RoutedSpec::parse(name)?))
                } else if let Some(rest) = name.strip_prefix("alpha") {
                    let (a, b) = rest.split_once("-bw").ok_or_else(|| {
                        format!("bad α–β fabric '{name}' (want alpha<SECONDS>-bw<BYTES/S>)")
                    })?;
                    let alpha_s: f64 =
                        a.parse().map_err(|e| format!("bad α in fabric '{name}': {e}"))?;
                    let bw_bps: f64 =
                        b.parse().map_err(|e| format!("bad bandwidth in fabric '{name}': {e}"))?;
                    Fabric::alpha_beta(alpha_s, bw_bps)
                } else if let Some(i) = Interconnect::by_name(name) {
                    Ok(Fabric::Interconnect(i))
                } else if let Some(c) = presets::by_name(name) {
                    Ok(Fabric::Cluster(c.name))
                } else {
                    Err(format!(
                        "unknown fabric '{name}' (try measured, ideal, stock, 10gbe, \
                         100gb-ib, a cluster preset, alpha<S>-bw<B/S>, or \
                         routed:<cluster>[:spine=<k>])"
                    ))
                }
            }
        }
    }
}

/// The per-collective cost model of `entry`'s gradient exchange on a
/// fabric: seconds for one all-reduce of `bytes`. Single-rank entries
/// communicate for free on every fabric. Hypothetical fabrics price the
/// hardware with the backend model (or the explicit α–β line) and carry
/// the entry's *fitted framework overhead* on top — the software cost
/// measured on the real system follows the workload to the new fabric.
pub fn channel(
    entry: &NetCalibration,
    fabric: &Fabric,
    fw: &Strategy,
) -> Result<Box<dyn Fn(f64) -> f64>, String> {
    channel_at(entry, fabric, fw, None)
}

/// [`channel`] at an optional hypothetical topology (see
/// [`replay::resolve_at`] via `resolve_at`): callers predicting a
/// *rescaled* entry pass the target layout so cluster/interconnect
/// fabrics are priced at the new participant count. With an explicit
/// topology, a cluster fabric smaller than the target is scaled out
/// like the measured cluster (that is what the axis asks for); without
/// one, the strict "does the job fit this fabric" check stands.
pub fn channel_at(
    entry: &NetCalibration,
    fabric: &Fabric,
    fw: &Strategy,
    at: Option<(usize, usize)>,
) -> Result<Box<dyn Fn(f64) -> f64>, String> {
    let (cluster, job) = resolve_at(entry, at)?;
    if job.ranks() <= 1 {
        return Ok(Box::new(|_| 0.0));
    }
    let overhead = entry.comm.map(|c| c.overhead_s).unwrap_or(0.0);
    match fabric {
        Fabric::Measured => {
            let cal = entry.calibrated_comm().ok_or_else(|| {
                format!("{}: no fitted comm channel to price collectives with", entry.key())
            })?;
            Ok(Box::new(move |bytes| cal.comm_time(bytes)))
        }
        Fabric::Ideal => Ok(Box::new(|_| 0.0)),
        Fabric::AlphaBeta { alpha_s, bw_bps } => {
            Fabric::alpha_beta(*alpha_s, *bw_bps)?; // reject NaN/negative pairs
            let link = Link::new(*alpha_s, *bw_bps);
            Ok(Box::new(move |bytes| overhead + link.xfer(bytes)))
        }
        Fabric::Cluster(name) => {
            let hypo = hypo_cluster_at(&entry.key(), name, &job, at)?;
            let topo = comm_topo(&hypo, job.nodes, job.gpus_per_node);
            let mut base = fw.clone();
            base.calibrated_comm = None;
            Ok(Box::new(move |bytes| overhead + base.comm_time(&topo, bytes)))
        }
        Fabric::Interconnect(i) => {
            // `cluster` is already scale-enlarged by `resolve_at` when a
            // hypothetical topology is in play.
            let mut swapped = cluster.clone();
            i.apply(&mut swapped);
            let topo = comm_topo(&swapped, job.nodes, job.gpus_per_node);
            let mut base = fw.clone();
            base.calibrated_comm = None;
            Ok(Box::new(move |bytes| overhead + base.comm_time(&topo, bytes)))
        }
        Fabric::Routed(spec) => match routed_collective_at(entry, spec, fw, at)? {
            Some(rc) => Ok(Box::new(move |bytes| overhead + rc.time(bytes))),
            None => {
                // gRPC parameter-server traffic serializes at the server
                // NIC; routing shares nothing beyond what the flat
                // backend model already prices.
                let hypo = hypo_cluster_at(&entry.key(), &spec.cluster, &job, at)?;
                let topo = comm_topo(&hypo, job.nodes, job.gpus_per_node);
                let mut base = fw.clone();
                base.calibrated_comm = None;
                Ok(Box::new(move |bytes| overhead + base.comm_time(&topo, bytes)))
            }
        },
    }
}

/// Resolve and scale-enlarge a named hypothetical cluster for a job —
/// the shared front half of the cluster and routed fabrics. Without an
/// explicit topology the strict "does the job fit this fabric" check
/// stands; with one, a smaller preset is scaled out like the measured
/// cluster (that is what the axis asks for).
fn hypo_cluster_at(
    entry_key: &str,
    name: &str,
    job: &JobSpec,
    at: Option<(usize, usize)>,
) -> Result<ClusterSpec, String> {
    let mut hypo =
        presets::by_name(name).ok_or_else(|| format!("unknown cluster fabric '{name}'"))?;
    let fits = job.nodes <= hypo.nodes && job.gpus_per_node <= hypo.gpus_per_node;
    if at.is_none() && !fits {
        return Err(format!(
            "{entry_key}: {}x{} GPUs do not fit fabric cluster '{}' ({}x{})",
            job.nodes, job.gpus_per_node, hypo.name, hypo.nodes, hypo.gpus_per_node
        ));
    }
    hypo.nodes = hypo.nodes.max(job.nodes);
    hypo.gpus_per_node = hypo.gpus_per_node.max(job.gpus_per_node);
    Ok(hypo)
}

/// The lowered routed collective of a routed-fabric prediction at an
/// entry's (optionally rescaled) layout — the link-level view shared by
/// [`channel_at`] pricing and [`fabric_link_usage`]. `Ok(None)` when
/// there is nothing to route: single-rank layouts, or the gRPC backend
/// (parameter-server traffic serializes at the server, so the flat
/// backend model already prices it and [`channel_at`] falls back there).
fn routed_collective_at(
    entry: &NetCalibration,
    spec: &RoutedSpec,
    fw: &Strategy,
    at: Option<(usize, usize)>,
) -> Result<Option<RoutedCollective>, String> {
    let (_, job) = resolve_at(entry, at)?;
    if job.ranks() <= 1 {
        return Ok(None);
    }
    let Backend::Nccl(algo) = fw.backend else {
        return Ok(None);
    };
    let hypo = hypo_cluster_at(&entry.key(), &spec.cluster, &job, at)?;
    let topo = comm_topo(&hypo, job.nodes, job.gpus_per_node);
    let rf = spec.fabric(&hypo, job.nodes, job.gpus_per_node);
    let rc = network::lower_allreduce(algo, &topo, &rf)
        .map_err(|e| format!("{} on '{}': {e}", entry.key(), spec.name()))?;
    Ok(Some(rc))
}

/// Per-link utilization of a what-if prediction on a routed fabric: the
/// flow count and peak bandwidth share of every fabric edge the lowered
/// collective crosses — the input to the obs layer's saturated-link
/// verdict. The max-min allocation is message-size-independent, so this
/// is a pure function of the scenario (fabric × entry × topology).
/// `Ok(None)` for non-routed fabrics and for routed predictions with no
/// shared graph to account (single rank, gRPC backend, dedicated links).
pub fn fabric_link_usage(
    entry: &NetCalibration,
    fabric: &Fabric,
    topo: Option<Topology>,
    fw: &Strategy,
) -> Result<Option<Vec<LinkUse>>, String> {
    let Fabric::Routed(spec) = fabric else {
        return Ok(None);
    };
    let (_, scaled, at) = rescaled_for(entry, topo, fw)?;
    let eff = scaled.as_ref().unwrap_or(entry);
    Ok(routed_collective_at(eff, spec, fw, at)?
        .map(|rc| rc.links)
        .filter(|links| !links.is_empty()))
}

/// The substituted per-layer collective-cost vector for an entry on a
/// fabric, or `None` for the measured fabric (replay the raw
/// measurements — the bit-identity contract).
pub fn comm_override(
    entry: &NetCalibration,
    fabric: &Fabric,
    fw: &Strategy,
) -> Result<Option<Vec<f64>>, String> {
    comm_override_at(entry, fabric, fw, None)
}

/// [`comm_override`] at an optional hypothetical topology.
pub fn comm_override_at(
    entry: &NetCalibration,
    fabric: &Fabric,
    fw: &Strategy,
    at: Option<(usize, usize)>,
) -> Result<Option<Vec<f64>>, String> {
    if matches!(fabric, Fabric::Measured) {
        return Ok(None);
    }
    let ch = channel_at(entry, fabric, fw, at)?;
    Ok(Some(
        entry
            .layers
            .iter()
            .map(|l| if l.size_bytes > 0 { ch(l.size_bytes as f64) } else { 0.0 })
            .collect(),
    ))
}

/// One what-if prediction: an entry's measured compute simulated against
/// a fabric (and optionally rescaled to a hypothetical topology), with
/// the measured-fabric replay *at the measured scale* as the baseline.
#[derive(Clone, Debug)]
pub struct Prediction {
    pub fabric: Fabric,
    /// Rescale target; `None` when predicting at the measured layout
    /// (an explicit target equal to the measured layout collapses here).
    pub topology: Option<Topology>,
    /// GPUs the prediction runs on (the target's ranks, or the entry's
    /// measured count).
    pub pred_gpus: usize,
    pub scheduler: SchedulerKind,
    pub replayed: Replayed,
    /// Sum of the substituted per-layer collective costs, seconds.
    pub comm_total_s: f64,
    /// Replay on the measured fabric at the measured scale under the
    /// same scheduler.
    pub measured_iter_s: f64,
}

impl Prediction {
    /// >1: the hypothetical fabric is faster than the measured one.
    pub fn speedup_vs_measured(&self) -> f64 {
        self.measured_iter_s / self.replayed.iter_time_s
    }
}

/// Predict one entry on one fabric under one scheduling policy. The
/// measured baseline is recomputed per prediction — campaign cells must
/// stay pure functions of their scenario (deterministic, cacheable);
/// sweeps that already hold the baseline pass it via
/// [`predict_entry_with_baseline`] instead.
pub fn predict_entry(
    entry: &NetCalibration,
    fabric: &Fabric,
    kind: SchedulerKind,
    fw: &Strategy,
) -> Result<Prediction, String> {
    predict_entry_at(entry, fabric, None, kind, fw, None)
}

/// [`predict_entry`] with an optional precomputed measured-fabric
/// baseline (the replay of `entry` under `kind`), so batch sweeps don't
/// re-simulate the identical baseline once per fabric. The replay is
/// deterministic, so a supplied baseline is bit-identical to a
/// recomputed one.
pub fn predict_entry_with_baseline(
    entry: &NetCalibration,
    fabric: &Fabric,
    kind: SchedulerKind,
    fw: &Strategy,
    baseline: Option<f64>,
) -> Result<Prediction, String> {
    predict_entry_at(entry, fabric, None, kind, fw, baseline)
}

/// The full prediction: one entry × one fabric × one (optional)
/// hypothetical topology × one scheduling policy. With a topology the
/// entry is first rescaled ([`rescale_entry`]) and replayed at the
/// target layout; a target equal to the measured layout collapses onto
/// the exact measured-layout code path, so "rescale to the scale you
/// measured at" is bit-identical to plain replay by construction.
pub fn predict_entry_at(
    entry: &NetCalibration,
    fabric: &Fabric,
    topo: Option<Topology>,
    kind: SchedulerKind,
    fw: &Strategy,
    baseline: Option<f64>,
) -> Result<Prediction, String> {
    Ok(predict_sim_at(entry, fabric, topo, kind, fw, baseline)?.0)
}

/// [`predict_entry_at`], keeping the replay's simulation artifacts (the
/// stamped DAG, resources and scheduled timeline) alive alongside the
/// prediction — the inputs `obs::breakdown` and the Chrome-trace
/// exporter explain it from. Same computation in the same order, so the
/// `Prediction` is bit-identical to the plain entry points.
pub fn predict_sim_at(
    entry: &NetCalibration,
    fabric: &Fabric,
    topo: Option<Topology>,
    kind: SchedulerKind,
    fw: &Strategy,
    baseline: Option<f64>,
) -> Result<(Prediction, replay::ReplaySim), String> {
    // The portfolio autotuner races every registered concrete policy
    // through this same entry point and keeps the winner's prediction
    // untouched (strict min on predicted iteration time, registry order
    // breaking ties), so a portfolio prediction is bit-identical to the
    // winning solo prediction by construction. The returned
    // `Prediction.scheduler` names the winner. Per-kind measured
    // baselines are recomputed — the caller's baseline was replayed
    // under the portfolio, not under any one concrete policy.
    if kind.is_portfolio() {
        let mut best: Option<(Prediction, replay::ReplaySim)> = None;
        for k in SchedulerKind::all() {
            let cand = predict_sim_at(entry, fabric, topo, k, fw, None)?;
            let better = match &best {
                None => true,
                Some((b, _)) => cand.0.replayed.iter_time_s < b.replayed.iter_time_s,
            };
            if better {
                best = Some(cand);
            }
        }
        return Ok(best.expect("the registry always has concrete policies"));
    }
    let (topo, scaled, at) = rescaled_for(entry, topo, fw)?;
    let eff = scaled.as_ref().unwrap_or(entry);
    let comm = comm_override_at(eff, fabric, fw, at)?;
    // The fusion policy must gang-launch at a cap tuned for the channel
    // it actually schedules: when a hypothetical fabric substitutes the
    // comm costs, scan against *that* fabric (replay's internal fallback
    // tunes against the fitted channel, which is only right for the
    // measured fabric).
    let cap = if kind == SchedulerKind::Fusion && comm.is_some() {
        fabric_fusion_cap(eff, fabric, fw, at)?
    } else {
        None
    };
    let rs = replay::replay_sim_with_comm_capped(eff, kind, fw, comm.as_deref(), at, cap)?;
    let replayed = rs.replayed.clone();
    let comm_total_s = match &comm {
        Some(v) => v.iter().sum(),
        None => eff.layers.iter().map(|l| l.comm_s).sum(),
    };
    // The measured-scale measured-fabric cell is its own baseline; every
    // hypothetical cell measures against the entry's own replay.
    let measured_iter_s = if comm.is_none() && at.is_none() {
        replayed.iter_time_s
    } else {
        match baseline {
            Some(b) => b,
            None => replay::replay_entry(entry, kind, fw)?.iter_time_s,
        }
    };
    let p = Prediction {
        fabric: fabric.clone(),
        topology: topo,
        pred_gpus: topo.map(|t| t.ranks()).unwrap_or(entry.gpus),
        scheduler: kind,
        replayed,
        comm_total_s,
        measured_iter_s,
    };
    Ok((p, rs))
}

/// Assemble the fusion-scan inputs of an entry against a channel at a
/// resolved job: gradient sizes, per-layer collective costs priced on
/// the channel, and the WFBP iteration inputs (one definition, shared
/// by the autotuner and the prediction-path cap scan via
/// [`replay::scan_iter_inputs`]).
fn scan_inputs(
    eff: &NetCalibration,
    cluster: &ClusterSpec,
    job: &JobSpec,
    ch: &dyn Fn(f64) -> f64,
) -> (Vec<f64>, Vec<f64>, eqs::IterInputs) {
    let pm = PerfModel::for_cluster(cluster);
    let h2d = (job.batch_per_gpu as u64 * job.net.input_bytes) as f64 / cluster.h2d_bw;
    let dur = replay::durations_from(eff, job, &pm, h2d);
    let bytes: Vec<f64> = eff.layers.iter().map(|l| l.size_bytes as f64).collect();
    let comm: Vec<f64> = eff
        .layers
        .iter()
        .map(|l| if l.size_bytes > 0 { ch(l.size_bytes as f64) } else { 0.0 })
        .collect();
    let inputs = replay::scan_iter_inputs(eff, cluster, job, h2d, &dur, comm.clone());
    (bytes, comm, inputs)
}

/// The optimal fusion bucket cap for an entry against a fabric's
/// channel at a layout — the scan half of [`autotune_fusion_at`],
/// reused by [`predict_entry_at`] to tune [`SchedulerKind::Fusion`]'s
/// gang-launch policy for the channel it actually schedules. `None`
/// when there is nothing to fuse (single rank, no gradient sizes).
fn fabric_fusion_cap(
    eff: &NetCalibration,
    fabric: &Fabric,
    fw: &Strategy,
    at: Option<(usize, usize)>,
) -> Result<Option<f64>, String> {
    let (cluster, job) = resolve_at(eff, at)?;
    if job.ranks() <= 1 {
        return Ok(None);
    }
    let ch = channel_at(eff, fabric, fw, at)?;
    let (bytes, _, inputs) = scan_inputs(eff, &cluster, &job, ch.as_ref());
    Ok(fusion::autotuned_cap(&inputs, &bytes, ch.as_ref()))
}

/// Result of autotuning the gradient-fusion bucket size against an
/// entry's channel on a fabric.
#[derive(Clone, Debug)]
pub struct FusionTune {
    /// Winning bucket-size cap, bytes.
    pub cap_bytes: f64,
    /// Buckets the winning cap partitions the gradient stream into.
    pub buckets: usize,
    /// Closed-form WFBP pipeline time at the winning cap (the scan
    /// objective, `analytic::fusion::pipeline_time`).
    pub scan_iter_s: f64,
    /// The winning bucket plan replayed through the DAG simulator
    /// (fused costs lowered via `fusion::fused_comm_vector`).
    pub replayed_iter_s: f64,
    /// Unfused (layer-wise) replay on the same fabric, for the gain.
    pub layerwise_iter_s: f64,
}

impl FusionTune {
    /// Replayed fusion gain over layer-wise exchange, percent.
    pub fn gain_pct(&self) -> f64 {
        100.0 * (self.layerwise_iter_s - self.replayed_iter_s) / self.layerwise_iter_s
    }
}

/// Run the bucket-size scan against the entry's channel on `fabric`
/// (for [`Fabric::Measured`], the profile's *fitted* α–β channel — the
/// ROADMAP's measurement-driven autotuning) and replay the winner.
/// Errors on single-rank entries, entries without gradient sizes, and
/// measured-fabric entries without a comm fit.
pub fn autotune_fusion(
    entry: &NetCalibration,
    fabric: &Fabric,
    fw: &Strategy,
) -> Result<FusionTune, String> {
    autotune_fusion_at(entry, fabric, fw, None)
}

/// [`autotune_fusion`] at an optional hypothetical topology: the entry
/// is rescaled first, so the scan runs against the channel *at the
/// target participant count* and the fused/layer-wise replays simulate
/// the target-scale DAG.
pub fn autotune_fusion_at(
    entry: &NetCalibration,
    fabric: &Fabric,
    fw: &Strategy,
    topo: Option<Topology>,
) -> Result<FusionTune, String> {
    let (_, scaled, at) = rescaled_for(entry, topo, fw)?;
    let eff = scaled.as_ref().unwrap_or(entry);
    let (cluster, job) = resolve_at(eff, at)?;
    if job.ranks() <= 1 {
        return Err(format!("{}: single-rank job has nothing to fuse", entry.key()));
    }
    let ch = channel_at(eff, fabric, fw, at)?;
    let (bytes, comm, inputs) = scan_inputs(eff, &cluster, &job, ch.as_ref());
    if bytes.iter().sum::<f64>() <= 0.0 {
        return Err(format!("{}: trace records no gradient sizes", entry.key()));
    }
    let (_, best) = fusion::optimal_bucket_bytes_with(&inputs, &bytes, ch.as_ref());
    let bucketing = fusion::bucketing_by_cap(&bytes, best.cap_bytes);
    let fused = fusion::fused_comm_vector(&bucketing, &bytes, ch.as_ref());
    let replayed =
        replay::replay_entry_with_comm_at(eff, SchedulerKind::Fifo, fw, Some(&fused), at)?;
    let layerwise =
        replay::replay_entry_with_comm_at(eff, SchedulerKind::Fifo, fw, Some(&comm), at)?;
    Ok(FusionTune {
        cap_bytes: best.cap_bytes,
        buckets: best.buckets,
        scan_iter_s: best.iter_time,
        replayed_iter_s: replayed.iter_time_s,
        layerwise_iter_s: layerwise.iter_time_s,
    })
}

/// Campaign scenarios for a what-if sweep: one cell per profile entry ×
/// topology × fabric × scheduler, tagged with the profile's content hash
/// plus the fabric and topology names, so cache entries stay
/// content-addressed exactly like `campaign --profile` cells. A `None`
/// topology predicts at the entry's own measured layout.
pub fn scenarios(
    profile: &CalibratedProfile,
    fabrics: &[Fabric],
    topologies: &[Option<Topology>],
    kinds: &[SchedulerKind],
) -> Vec<Scenario> {
    let mut out = Vec::with_capacity(
        profile.entries.len() * fabrics.len() * topologies.len() * kinds.len(),
    );
    for base in replay::scenarios(profile, kinds) {
        for topo in topologies {
            for fabric in fabrics {
                let mut s = base.clone();
                s.fabric = Some(fabric.name());
                s.topology = topo.map(|t| t.name());
                out.push(s);
            }
        }
    }
    out
}

/// A prediction lowered into the flat campaign metric map.
fn metrics_of(p: &Prediction) -> CellResult {
    let mut r = CellResult::new();
    r.set("iter_time_s", p.replayed.iter_time_s)
        .set("samples_per_s", p.replayed.samples_per_s)
        .set("makespan_s", p.replayed.makespan_s)
        .set("comm_total_s", p.comm_total_s)
        .set("measured_iter_s", p.measured_iter_s)
        .set("speedup_vs_measured", p.speedup_vs_measured())
        .set("pred_gpus", p.pred_gpus as f64);
    r
}

/// The topology a what-if scenario predicts at (`None`: the measured
/// layout). Scenarios reach cells only after [`validate_whatif`].
fn cell_topology(s: &Scenario) -> Option<Topology> {
    s.topology
        .as_deref()
        .map(|t| Topology::parse(t).expect("topology validated before sweep"))
}

/// Measured baselines for the cells of a sweep — one replay per entry ×
/// scheduler that appears in a *hypothetical* cell (non-measured fabric
/// or explicit topology); measured-scale measured-fabric cells are
/// their own baseline and add nothing. [`rows`] injects this and
/// `campaign --profile` passes it to [`whatif_cell_with`], so a sweep
/// never re-simulates the identical baseline once per fabric × topology
/// cell — and a `--filter`ed sweep only pays for the cells it keeps.
pub fn measured_baselines(
    profile: &CalibratedProfile,
    cells: &[Scenario],
) -> Result<BTreeMap<(String, String), f64>, String> {
    let fw = strategy::by_name(&profile.framework)
        .ok_or_else(|| format!("unknown framework '{}' in profile", profile.framework))?;
    let mut out = BTreeMap::new();
    for s in cells {
        if s.fabric.as_deref() == Some("measured") && s.topology.is_none() {
            continue; // its own baseline
        }
        if s.scheduler.is_portfolio() {
            // The race recomputes per-concrete-kind baselines inside
            // `predict_sim_at`; a portfolio-keyed baseline would never
            // be read.
            continue;
        }
        let Some(entry) = replay::entry_for(profile, s) else {
            continue; // validated sweeps never hit this
        };
        let key = (entry.key(), s.scheduler.name().to_string());
        if out.contains_key(&key) {
            continue;
        }
        let base = replay::replay_entry(entry, s.scheduler, &fw)
            .map_err(|e| format!("{}: {e}", entry.key()))?;
        out.insert(key, base.iter_time_s);
    }
    Ok(out)
}

/// The per-cell measurement of what-if sweeps: predict the matching
/// entry on the cell's fabric × topology under the cell's scheduler,
/// recomputing the measured baseline in-cell (pure function of the
/// scenario — deterministic, cacheable). Batch sweeps precompute the
/// baselines once and use [`whatif_cell_with`]; the replay is
/// deterministic, so the two are bit-identical.
pub fn whatif_cell(profile: &CalibratedProfile, s: &Scenario) -> CellResult {
    whatif_cell_with(profile, s, &BTreeMap::new())
}

/// [`whatif_cell`] with precomputed measured baselines
/// ([`measured_baselines`]); cells missing from the map recompute
/// theirs.
pub fn whatif_cell_with(
    profile: &CalibratedProfile,
    s: &Scenario,
    baselines: &BTreeMap<(String, String), f64>,
) -> CellResult {
    let fw = strategy::by_name(&profile.framework).expect("profile validated before sweep");
    let entry = replay::entry_for(profile, s).expect("scenario was built from this profile");
    let fabric = Fabric::parse(s.fabric.as_deref().expect("whatif cells carry a fabric"))
        .expect("fabric validated before sweep");
    let base = baselines
        .get(&(entry.key(), s.scheduler.name().to_string()))
        .copied();
    let (p, rs) = predict_sim_at(entry, &fabric, cell_topology(s), s.scheduler, &fw, base)
        .expect("fabric/topology validated before sweep");
    let mut r = metrics_of(&p);
    // The makespan lower bound of the predicted DAG on the predicted
    // resources — no schedule can beat it, so `gap_to_bound` is how much
    // of the row is the policy's fault rather than the hardware's.
    let bound = lower_bound::makespan_lower_bound(&rs.dag, &rs.res.pool);
    r.set("lower_bound_s", bound)
        .set("gap_to_bound", lower_bound::gap_to_bound(p.replayed.makespan_s, bound));
    if s.scheduler.is_portfolio() {
        r.set("portfolio_winner_code", p.scheduler.index() as f64);
    }
    // The obs breakdown rides the flat metric map, so explanations are
    // content-addressed alongside the cell in both result caches.
    for (k, v) in rs.breakdown().metric_pairs() {
        r.set(k, v);
    }
    r
}

/// Pre-sweep gate: the profile must be sweepable, every entry must be
/// rescalable to every requested topology (fitted channel present,
/// target in range), and every rescaled entry must be pricable on every
/// requested fabric — so a bad axis value fails with a message before
/// workers spawn, never as a panic inside the pool. The measured fabric
/// is exempt from the channel check — prediction on it replays raw (or
/// re-priced) measurements and needs no extra fit.
pub fn validate_whatif(
    profile: &CalibratedProfile,
    fabrics: &[Fabric],
    topologies: &[Option<Topology>],
) -> Result<(), String> {
    replay::validate_profile(profile)?;
    if fabrics.is_empty() {
        return Err("no fabrics to sweep".into());
    }
    if topologies.is_empty() {
        return Err("no topologies to sweep".into());
    }
    let fw = strategy::by_name(&profile.framework).expect("validate_profile checked the name");
    for entry in &profile.entries {
        for topo in topologies {
            let (_, scaled, at) = rescaled_for(entry, *topo, &fw)
                .map_err(|e| format!("{}: {e}", entry.key()))?;
            let eff = scaled.as_ref().unwrap_or(entry);
            for fabric in fabrics {
                if matches!(fabric, Fabric::Measured) {
                    continue;
                }
                channel_at(eff, fabric, &fw, at)
                    .map_err(|e| format!("{} on fabric '{}': {e}", entry.key(), fabric.name()))?;
            }
        }
    }
    Ok(())
}

/// One report row: an entry × topology × fabric × scheduler prediction,
/// with the optional fusion autotune attached (shared across the
/// schedulers of the same entry × topology × fabric).
#[derive(Clone, Debug)]
pub struct WhatIfRow {
    pub net: String,
    pub cluster: String,
    /// GPUs the entry was *measured* on.
    pub gpus: usize,
    pub batch: usize,
    pub fabric: String,
    /// Layout the prediction runs at (`"<nodes>x<gpus>"`; the measured
    /// layout for measured-scale rows).
    pub topology: String,
    /// GPUs the prediction runs on (`nodes × gpus_per_node` of
    /// `topology`).
    pub pred_gpus: usize,
    pub scheduler: SchedulerKind,
    pub iter_time_s: f64,
    pub samples_per_s: f64,
    pub comm_total_s: f64,
    pub measured_iter_s: f64,
    pub speedup_vs_measured: f64,
    /// Makespan lower bound of the predicted DAG on the predicted
    /// resources ([`lower_bound::makespan_lower_bound`]); `None` only
    /// for cells from caches that predate the bound columns.
    pub lower_bound_s: Option<f64>,
    /// Relative gap of the predicted makespan above `lower_bound_s`
    /// ([`lower_bound::gap_to_bound`]), same provenance.
    pub gap_to_bound: Option<f64>,
    /// The concrete policy a `portfolio` row's race selected; `None` on
    /// solo-policy rows.
    pub portfolio_winner: Option<SchedulerKind>,
    pub fusion: Option<FusionTune>,
    /// The obs breakdown metrics of the predicted timeline, keyed by
    /// [`breakdown::METRIC_KEYS`]. `None` only for cells from caches
    /// that predate the obs layer.
    pub explain: Option<BTreeMap<String, f64>>,
    /// Per-link utilization of the routed fabric graph the prediction's
    /// collectives crossed ([`fabric_link_usage`]); `None` off routed
    /// fabrics and when no link is shared.
    pub links: Option<Vec<LinkUse>>,
}

/// Sweep a profile across topologies × fabrics × schedulers on `jobs`
/// workers and shape the cells into report rows. With `autotune`, each
/// entry × topology × fabric additionally carries the fusion autotune
/// (entries that cannot fuse — single rank, no gradient sizes, measured
/// fabric without a comm fit — get `fusion: None` instead of failing
/// the sweep).
pub fn rows(
    profile: &CalibratedProfile,
    fabrics: &[Fabric],
    topologies: &[Option<Topology>],
    kinds: &[SchedulerKind],
    autotune: bool,
    jobs: usize,
) -> Result<Vec<WhatIfRow>, String> {
    validate_whatif(profile, fabrics, topologies)?;
    if kinds.is_empty() {
        return Err("no schedulers to sweep".into());
    }
    let fw = strategy::by_name(&profile.framework).expect("validated");

    let cells = scenarios(profile, fabrics, topologies, kinds);
    // Measured baselines once per entry × scheduler (the replay is
    // deterministic, so injecting them into every prediction is
    // bit-identical to the cells recomputing them per cell). Empty —
    // and unused — when the sweep holds only measured-scale
    // measured-fabric cells, which are their own baseline.
    let baselines = measured_baselines(profile, &cells)?;
    let outcome =
        runner::run_with(&cells, jobs, None, |s| whatif_cell_with(profile, s, &baselines));

    // Fusion autotunes are scheduler-independent: one per entry ×
    // topology × fabric, fanned through the same worker pool (they are
    // the heaviest stage — a bucket-cap scan plus two replays each).
    let mut tunes: BTreeMap<(String, String, String), FusionTune> = BTreeMap::new();
    if autotune {
        let tune_cells = scenarios(profile, fabrics, topologies, &[SchedulerKind::Fifo]);
        let tuned = runner::run_with(&tune_cells, jobs, None, |s| {
            let entry =
                replay::entry_for(profile, s).expect("scenario was built from this profile");
            let fabric = Fabric::parse(s.fabric.as_deref().expect("whatif cells carry a fabric"))
                .expect("fabric validated before sweep");
            let mut r = CellResult::new();
            // Entries that cannot fuse (single rank, no gradient sizes,
            // measured fabric without a comm fit) yield an empty cell.
            if let Ok(t) = autotune_fusion_at(entry, &fabric, &fw, cell_topology(s)) {
                r.set("cap_bytes", t.cap_bytes)
                    .set("buckets", t.buckets as f64)
                    .set("scan_iter_s", t.scan_iter_s)
                    .set("replayed_iter_s", t.replayed_iter_s)
                    .set("layerwise_iter_s", t.layerwise_iter_s);
            }
            r
        });
        for (s, r) in &tuned.cells {
            let entry = replay::entry_for(profile, s).expect("tune scenario from this profile");
            let fabric_name = s.fabric.clone().expect("whatif cells carry a fabric");
            let topo_name = s.topology.clone().unwrap_or_else(|| "-".into());
            if let Some(cap_bytes) = r.get("cap_bytes") {
                tunes.insert(
                    (entry.key(), topo_name, fabric_name),
                    FusionTune {
                        cap_bytes,
                        buckets: r.get("buckets").expect("tune cell metric") as usize,
                        scan_iter_s: r.get("scan_iter_s").expect("tune cell metric"),
                        replayed_iter_s: r.get("replayed_iter_s").expect("tune cell metric"),
                        layerwise_iter_s: r.get("layerwise_iter_s").expect("tune cell metric"),
                    },
                );
            }
        }
    }

    let mut out = Vec::with_capacity(outcome.cells.len());
    for (s, r) in &outcome.cells {
        let entry = replay::entry_for(profile, s).expect("scenario was built from this profile");
        let fabric_name = s.fabric.clone().expect("whatif cells carry a fabric");
        let topo_key = s.topology.clone().unwrap_or_else(|| "-".into());
        // Display layout: the predicted scale, or the measured one
        // (replay::scenarios stamps it on the base cell).
        let topo_name = s
            .topology
            .clone()
            .unwrap_or_else(|| format!("{}x{}", s.nodes, s.gpus_per_node));
        let metric = |k: &str| r.get(k).expect("whatif cell metric");
        let mut explain: BTreeMap<String, f64> = BTreeMap::new();
        for k in breakdown::METRIC_KEYS {
            if let Some(v) = r.get(k) {
                explain.insert(k.to_string(), v);
            }
        }
        let explain = (explain.len() == breakdown::METRIC_KEYS.len()).then_some(explain);
        // Per-link fabric usage is a pure function of the scenario (the
        // max-min rates are message-size-independent), so it is computed
        // at assembly time instead of riding the cached flat metric map.
        let links = Fabric::parse(&fabric_name)
            .ok()
            .and_then(|fab| fabric_link_usage(entry, &fab, cell_topology(s), &fw).ok())
            .flatten();
        out.push(WhatIfRow {
            net: s.net.clone(),
            cluster: s.cluster.clone(),
            gpus: entry.gpus,
            batch: entry.batch,
            fabric: fabric_name.clone(),
            topology: topo_name,
            pred_gpus: metric("pred_gpus") as usize,
            scheduler: s.scheduler,
            iter_time_s: metric("iter_time_s"),
            samples_per_s: metric("samples_per_s"),
            comm_total_s: metric("comm_total_s"),
            measured_iter_s: metric("measured_iter_s"),
            speedup_vs_measured: metric("speedup_vs_measured"),
            lower_bound_s: r.get("lower_bound_s"),
            gap_to_bound: r.get("gap_to_bound"),
            portfolio_winner: r
                .get("portfolio_winner_code")
                .and_then(|c| SchedulerKind::from_index(c as usize)),
            fusion: tunes.get(&(entry.key(), topo_key, fabric_name)).cloned(),
            explain,
            links,
        });
    }
    Ok(out)
}

/// Render the human table.
pub fn render(rows: &[WhatIfRow]) -> String {
    let mut t = Table::new(&[
        "net",
        "cluster",
        "gpus",
        "topo",
        "fabric",
        "scheduler",
        "measured",
        "predicted",
        "speedup",
        "vs bound",
        "comm",
        "fusion cap",
        "fusion gain",
    ]);
    for r in rows {
        let (cap, gain) = match &r.fusion {
            Some(tune) => (fmt_bytes(tune.cap_bytes), format!("{}%", f(tune.gain_pct(), 1))),
            None => ("-".into(), "-".into()),
        };
        // Portfolio rows name the concrete policy the race selected.
        let sched = match r.portfolio_winner {
            Some(w) => format!("{}→{}", r.scheduler.name(), w.name()),
            None => r.scheduler.name().to_string(),
        };
        let gap = r
            .gap_to_bound
            .map(|g| format!("+{}%", f(100.0 * g, 1)))
            .unwrap_or_else(|| "-".into());
        t.row(&[
            r.net.clone(),
            r.cluster.clone(),
            r.gpus.to_string(),
            r.topology.clone(),
            r.fabric.clone(),
            sched,
            fmt_dur(r.measured_iter_s),
            fmt_dur(r.iter_time_s),
            format!("{}x", f(r.speedup_vs_measured, 2)),
            gap,
            fmt_dur(r.comm_total_s),
            cap,
            gain,
        ]);
    }
    t.render()
}

/// Render the `--explain` companion table: where each predicted
/// iteration's critical path goes, how much communication the
/// prediction is actually exposed to, and what bounds it.
pub fn render_explain(rows: &[WhatIfRow]) -> String {
    let mut t = Table::new(&[
        "net",
        "topo",
        "fabric",
        "scheduler",
        "bottleneck",
        "comm exposed",
        "exposed %",
        "cp compute",
        "cp comm",
        "cp io",
        "cp bubble",
        "hot link",
    ]);
    for r in rows {
        let m = |k: &str| r.explain.as_ref().and_then(|e| e.get(k).copied());
        let dash = || "-".to_string();
        let label = m("bottleneck_code")
            .and_then(Bottleneck::from_code)
            .map(|b| b.name().to_string())
            .unwrap_or_else(dash);
        let dur = |k: &str| m(k).map(fmt_dur).unwrap_or_else(dash);
        let pair = |a: &str, b: &str| match (m(a), m(b)) {
            (Some(x), Some(y)) => fmt_dur(x + y),
            _ => dash(),
        };
        let frac = m("comm_exposed_frac")
            .map(|v| format!("{}%", f(100.0 * v, 1)))
            .unwrap_or_else(dash);
        t.row(&[
            r.net.clone(),
            r.topology.clone(),
            r.fabric.clone(),
            r.scheduler.name().to_string(),
            label,
            dur("comm_exposed_s"),
            frac,
            pair("cp_fwd_s", "cp_bwd_s"),
            dur("cp_agg_s"),
            pair("cp_io_s", "cp_h2d_s"),
            dur("cp_bubble_s"),
            r.links
                .as_deref()
                .map(breakdown::link_verdict)
                .unwrap_or_else(dash),
        ]);
    }
    t.render()
}

/// Serialize the report (schema v`WHATIF_SCHEMA_VERSION`). Rows that
/// carry the obs breakdown additionally emit an `explain` section
/// (schema v`EXPLAIN_SCHEMA_VERSION`, aligned with `rows`).
pub fn report_to_json(rows: &[WhatIfRow], framework: &str, profile_tag: &str) -> Json {
    let row_json: Vec<Json> = rows
        .iter()
        .map(|r| {
            let fusion = match &r.fusion {
                None => Json::Null,
                Some(t) => Json::obj(vec![
                    ("cap_bytes", Json::num(t.cap_bytes)),
                    ("buckets", Json::num(t.buckets as f64)),
                    ("scan_iter_s", Json::num(t.scan_iter_s)),
                    ("replayed_iter_s", Json::num(t.replayed_iter_s)),
                    ("layerwise_iter_s", Json::num(t.layerwise_iter_s)),
                ]),
            };
            let links = match &r.links {
                None => Json::Null,
                Some(ls) => Json::Arr(
                    ls.iter()
                        .map(|l| {
                            Json::obj(vec![
                                ("link", Json::str(l.label.clone())),
                                ("utilization", Json::num(l.utilization)),
                                ("flows", Json::num(l.flows as f64)),
                            ])
                        })
                        .collect(),
                ),
            };
            Json::obj(vec![
                ("net", Json::str(r.net.clone())),
                ("cluster", Json::str(r.cluster.clone())),
                ("gpus", Json::num(r.gpus as f64)),
                ("batch", Json::num(r.batch as f64)),
                ("fabric", Json::str(r.fabric.clone())),
                ("topology", Json::str(r.topology.clone())),
                ("pred_gpus", Json::num(r.pred_gpus as f64)),
                ("scheduler", Json::str(r.scheduler.name())),
                ("iter_time_s", Json::num(r.iter_time_s)),
                ("samples_per_s", Json::num(r.samples_per_s)),
                ("comm_total_s", Json::num(r.comm_total_s)),
                ("measured_iter_s", Json::num(r.measured_iter_s)),
                ("speedup_vs_measured", Json::num(r.speedup_vs_measured)),
                (
                    "lower_bound_s",
                    r.lower_bound_s.map(Json::num).unwrap_or(Json::Null),
                ),
                (
                    "gap_to_bound",
                    r.gap_to_bound.map(Json::num).unwrap_or(Json::Null),
                ),
                (
                    "portfolio_winner",
                    r.portfolio_winner
                        .map(|w| Json::str(w.name()))
                        .unwrap_or(Json::Null),
                ),
                ("fusion", fusion),
                ("links", links),
            ])
        })
        .collect();
    let mut doc = vec![
        ("schema_version", Json::num(WHATIF_SCHEMA_VERSION as f64)),
        ("bench", Json::str("whatif")),
        ("framework", Json::str(framework)),
        ("profile", Json::str(profile_tag)),
        ("rows", Json::Arr(row_json)),
    ];
    if rows.iter().any(|r| r.explain.is_some()) {
        let explained: Vec<Json> = rows
            .iter()
            .map(|r| match &r.explain {
                Some(e) => breakdown::explain_json(&|k| e.get(k).copied()).unwrap_or(Json::Null),
                None => Json::Null,
            })
            .collect();
        doc.push((
            "explain",
            Json::obj(vec![
                ("schema_version", Json::num(EXPLAIN_SCHEMA_VERSION as f64)),
                ("rows", Json::Arr(explained)),
            ]),
        ));
    }
    Json::obj(doc)
}

/// Validate a `BENCH_whatif.json` against schema v3 — or v2, which
/// differs only in lacking the optional bound/portfolio columns — and,
/// when present, its `explain` section against schema v1. Returns the
/// row count.
pub fn validate_report(report: &Json) -> Result<usize, String> {
    let version = report
        .get("schema_version")
        .and_then(|v| v.as_f64())
        .ok_or("missing schema_version")?;
    if version != 2.0 && version != WHATIF_SCHEMA_VERSION as f64 {
        return Err(format!(
            "schema_version {version} is not supported (want 2 or {WHATIF_SCHEMA_VERSION})"
        ));
    }
    if report.get("bench").and_then(|v| v.as_str()) != Some("whatif") {
        return Err("bench field must be \"whatif\"".into());
    }
    for field in ["framework", "profile"] {
        report
            .get(field)
            .and_then(|v| v.as_str())
            .ok_or_else(|| format!("missing string field '{field}'"))?;
    }
    let rows = report
        .get("rows")
        .and_then(|v| v.as_arr())
        .ok_or("missing rows array")?;
    if rows.is_empty() {
        return Err("rows array is empty".into());
    }
    let req_num = |row: &Json, field: &str, at: &str| -> Result<f64, String> {
        let v = row
            .get(field)
            .and_then(|v| v.as_f64())
            .ok_or_else(|| format!("{at}: missing numeric field '{field}'"))?;
        if !v.is_finite() || v < 0.0 {
            return Err(format!("{at}: field '{field}' must be finite and ≥ 0"));
        }
        Ok(v)
    };
    for (i, row) in rows.iter().enumerate() {
        let at = format!("rows[{i}]");
        for field in ["net", "cluster", "fabric", "topology", "scheduler"] {
            row.get(field)
                .and_then(|v| v.as_str())
                .ok_or_else(|| format!("{at}: missing string field '{field}'"))?;
        }
        for field in [
            "gpus",
            "batch",
            "pred_gpus",
            "iter_time_s",
            "samples_per_s",
            "comm_total_s",
            "measured_iter_s",
            "speedup_vs_measured",
        ] {
            req_num(row, field, &at)?;
        }
        // comm_total_s may legitimately be 0 (ideal fabric, single GPU);
        // everything else must be positive.
        for field in [
            "gpus",
            "pred_gpus",
            "iter_time_s",
            "samples_per_s",
            "measured_iter_s",
            "speedup_vs_measured",
        ] {
            if row.get(field).and_then(|v| v.as_f64()) == Some(0.0) {
                return Err(format!("{at}: field '{field}' must be positive"));
            }
        }
        // The v3 bound/portfolio columns are optional (cells from caches
        // that predate them degrade to null), but when present they must
        // be coherent: finite non-negative bound and gap, and a winner
        // the scheduler registry actually resolves to a concrete policy.
        for field in ["lower_bound_s", "gap_to_bound"] {
            match row.get(field) {
                None | Some(Json::Null) => {}
                Some(_) => {
                    req_num(row, field, &at)?;
                }
            }
        }
        match row.get("portfolio_winner") {
            None | Some(Json::Null) => {}
            Some(w) => {
                let name = w
                    .as_str()
                    .ok_or_else(|| format!("{at}: 'portfolio_winner' must be a string"))?;
                let k = SchedulerKind::by_name(name).ok_or_else(|| {
                    format!("{at}: portfolio_winner '{name}' is not a registered scheduler")
                })?;
                if k.is_portfolio() {
                    return Err(format!(
                        "{at}: portfolio_winner must be a concrete policy, not '{name}'"
                    ));
                }
            }
        }
        match row.get("fusion") {
            None | Some(Json::Null) => {}
            Some(fusion) => {
                for field in [
                    "cap_bytes",
                    "buckets",
                    "scan_iter_s",
                    "replayed_iter_s",
                    "layerwise_iter_s",
                ] {
                    let v = req_num(fusion, field, &format!("{at}.fusion"))?;
                    if v <= 0.0 {
                        return Err(format!("{at}.fusion: field '{field}' must be positive"));
                    }
                }
            }
        }
        match row.get("links") {
            None | Some(Json::Null) => {}
            Some(links) => {
                let arr = links
                    .as_arr()
                    .ok_or_else(|| format!("{at}: 'links' must be null or an array"))?;
                for (j, l) in arr.iter().enumerate() {
                    let lat = format!("{at}.links[{j}]");
                    l.get("link")
                        .and_then(|v| v.as_str())
                        .ok_or_else(|| format!("{lat}: missing string field 'link'"))?;
                    let u = req_num(l, "utilization", &lat)?;
                    // A max-min share can never exceed its link's capacity.
                    if u > 1.0 {
                        return Err(format!("{lat}: utilization {u} exceeds capacity"));
                    }
                    let flows = req_num(l, "flows", &lat)?;
                    if flows < 1.0 {
                        return Err(format!("{lat}: 'flows' must be ≥ 1"));
                    }
                }
            }
        }
    }
    if let Some(explain) = report.get("explain") {
        let v = explain
            .get("schema_version")
            .and_then(|v| v.as_f64())
            .ok_or("explain: missing schema_version")?;
        if v != EXPLAIN_SCHEMA_VERSION as f64 {
            return Err(format!(
                "explain schema_version {v} != supported {EXPLAIN_SCHEMA_VERSION}"
            ));
        }
        let erows = explain
            .get("rows")
            .and_then(|v| v.as_arr())
            .ok_or("explain: missing rows array")?;
        if erows.len() != rows.len() {
            return Err(format!(
                "explain has {} rows but the report has {}",
                erows.len(),
                rows.len()
            ));
        }
        for (i, e) in erows.iter().enumerate() {
            if matches!(e, Json::Null) {
                continue;
            }
            let at = format!("explain.rows[{i}]");
            for section in ["phases", "critical_path", "comm"] {
                e.get(section).ok_or_else(|| format!("{at}: missing '{section}' object"))?;
            }
            let label = e
                .get("bottleneck")
                .and_then(|v| v.as_str())
                .ok_or_else(|| format!("{at}: missing bottleneck label"))?;
            let known = ["compute-bound", "comm-bound", "io-bound", "update-bound"];
            if !known.contains(&label) {
                return Err(format!("{at}: unknown bottleneck '{label}'"));
            }
            for (section, field) in
                [("critical_path", "bubble_s"), ("comm", "exposed_s"), ("comm", "hidden_s")]
            {
                let v = e
                    .get(section)
                    .and_then(|s| s.get(field))
                    .and_then(|v| v.as_f64())
                    .ok_or_else(|| format!("{at}.{section}: missing numeric '{field}'"))?;
                if !v.is_finite() || v < 0.0 {
                    return Err(format!("{at}.{section}.{field} must be finite and ≥ 0"));
                }
            }
        }
    }
    Ok(rows.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::fit::calibrate_one;
    use crate::dag::builder::JobSpec;
    use crate::frameworks::strategy as fws;
    use crate::models::zoo;
    use crate::trace::synth::synth_trace;
    use crate::util::json;

    fn entry_of(
        net: crate::models::layer::NetSpec,
        cluster: &crate::cluster::topology::ClusterSpec,
        nodes: usize,
        gpn: usize,
    ) -> NetCalibration {
        let job = JobSpec {
            batch_per_gpu: net.default_batch,
            net,
            nodes,
            gpus_per_node: gpn,
            iterations: 1,
        };
        let t = synth_trace(cluster, &job, &fws::caffe_mpi(), 10, 23);
        calibrate_one(&t, &fws::caffe_mpi()).unwrap()
    }

    fn profile_for(cluster: &crate::cluster::topology::ClusterSpec) -> CalibratedProfile {
        CalibratedProfile {
            framework: "caffe-mpi".into(),
            entries: vec![
                entry_of(zoo::alexnet(), cluster, 2, 4),
                entry_of(zoo::resnet50(), cluster, 4, 4),
            ],
        }
    }

    #[test]
    fn fabric_names_round_trip() {
        let fabrics = [
            Fabric::Measured,
            Fabric::Ideal,
            Fabric::Cluster("v100-nvlink-ib".into()),
            Fabric::Interconnect(Interconnect::TenGbE),
            Fabric::Interconnect(Interconnect::Stock),
            Fabric::alpha_beta(4e-5, 1.25e9).unwrap(),
            Fabric::parse("routed:v100:dedicated").unwrap(),
            Fabric::parse("routed:k80:spine=2").unwrap(),
        ];
        for f in &fabrics {
            let back = Fabric::parse(&f.name()).unwrap_or_else(|e| panic!("{}: {e}", f.name()));
            assert_eq!(&back, f, "{}", f.name());
        }
        assert!(Fabric::parse("warpdrive").is_err());
        assert!(Fabric::parse("alpha1e-5").is_err(), "missing -bw part");
        assert!(Fabric::parse("routed:warpdrive").is_err(), "unknown preset");
        assert!(Fabric::parse("routed:v100:spine=-1").is_err());
        assert!(Fabric::alpha_beta(-1.0, 1e9).is_err());
        assert!(Fabric::alpha_beta(0.0, 0.0).is_err());
        // Short cluster aliases canonicalize to the full preset name.
        assert_eq!(Fabric::parse("v100").unwrap().name(), "v100-nvlink-ib");
        // A bare routed fabric defaults to the shared spine.
        assert_eq!(
            Fabric::parse("routed:v100").unwrap().name(),
            format!("routed:v100-nvlink-ib:spine={}", network::DEFAULT_SPINE_FLOWS)
        );
    }

    /// The tentpole's bit-identity keystone at the what-if level: routed
    /// pricing over dedicated links is the flat backend model, so a
    /// `routed:<cluster>:dedicated` prediction is bit-identical to the
    /// plain cluster fabric — at the measured layout and rescaled.
    #[test]
    fn routed_dedicated_fabric_matches_cluster_fabric() {
        let cluster = crate::cluster::presets::k80_cluster();
        let entry = entry_of(zoo::alexnet(), &cluster, 2, 4);
        let fw = fws::caffe_mpi();
        let flat = Fabric::Cluster("k80-pcie-10gbe".into());
        let routed = Fabric::parse("routed:k80:dedicated").unwrap();
        for topo in [None, Some(Topology::new(8, 4).unwrap())] {
            let pf =
                predict_entry_at(&entry, &flat, topo, SchedulerKind::Fifo, &fw, None).unwrap();
            let pr =
                predict_entry_at(&entry, &routed, topo, SchedulerKind::Fifo, &fw, None).unwrap();
            assert_eq!(
                pf.replayed.iter_time_s.to_bits(),
                pr.replayed.iter_time_s.to_bits(),
                "dedicated routing must be bit-identical at {topo:?}"
            );
            assert_eq!(pf.comm_total_s.to_bits(), pr.comm_total_s.to_bits());
        }
        // Nothing is shared on dedicated links, so there is no link
        // ledger to report.
        assert_eq!(fabric_link_usage(&entry, &routed, None, &fw).unwrap(), None);
    }

    /// The contention keystone: a shared-spine routed fabric is never
    /// faster than the flat (infinite-backplane) model of the same
    /// cluster, the gap grows as a 2-node profile is laddered past the
    /// spine's line-rate flow budget, and the saturated link is named.
    #[test]
    fn routed_spine_contends_and_names_the_saturated_link() {
        let cluster = crate::cluster::presets::k80_cluster();
        let entry = entry_of(zoo::resnet50(), &cluster, 2, 4);
        let fw = fws::caffe_mpi();
        let flat = Fabric::Cluster("k80-pcie-10gbe".into());
        let routed = Fabric::parse("routed:k80:spine=4").unwrap();
        let bytes = 25e6;
        let mut prev = 0.0;
        for nodes in [2usize, 4, 8, 16, 64] {
            let at = Some((nodes, 4));
            let cf = channel_at(&entry, &flat, &fw, at).unwrap();
            let cr = channel_at(&entry, &routed, &fw, at).unwrap();
            assert!(
                cr(bytes) > cf(bytes),
                "{nodes} nodes: routed {} must exceed flat {}",
                cr(bytes),
                cf(bytes)
            );
            assert!(cr(bytes) > prev, "{nodes} nodes: contention must grow");
            prev = cr(bytes);
        }
        // The full prediction agrees: more comm, never a faster iteration.
        let topo = Some(Topology::new(8, 4).unwrap());
        let pf = predict_entry_at(&entry, &flat, topo, SchedulerKind::Fifo, &fw, None).unwrap();
        let pr = predict_entry_at(&entry, &routed, topo, SchedulerKind::Fifo, &fw, None).unwrap();
        assert!(pr.comm_total_s > pf.comm_total_s);
        assert!(pr.replayed.iter_time_s >= pf.replayed.iter_time_s - 1e-12);
        // Past the spine's flow budget (4 line-rate flows, 8 node rings
        // crossing), the backplane is the named bottleneck.
        let links = fabric_link_usage(&entry, &routed, topo, &fw).unwrap().unwrap();
        let hot = breakdown::saturated_link(&links).expect("spine must saturate at 8 nodes");
        assert_eq!(hot.label, "spine-backplane");
        assert_eq!(hot.flows, 8);
        assert!(hot.utilization >= 0.999);
        assert!(breakdown::link_verdict(&links).contains("spine-backplane saturated"));
    }

    /// The bit-identity contract: the measured fabric takes the exact
    /// replay code path.
    #[test]
    fn measured_fabric_is_bit_identical_to_replay() {
        let cluster = crate::cluster::presets::k80_cluster();
        let entry = entry_of(zoo::alexnet(), &cluster, 2, 4);
        let fw = fws::caffe_mpi();
        for kind in [SchedulerKind::Fifo, SchedulerKind::Priority] {
            let p = predict_entry(&entry, &Fabric::Measured, kind, &fw).unwrap();
            let r = replay::replay_entry(&entry, kind, &fw).unwrap();
            assert_eq!(p.replayed.iter_time_s.to_bits(), r.iter_time_s.to_bits());
            assert_eq!(p.replayed.makespan_s.to_bits(), r.makespan_s.to_bits());
            assert_eq!(p.speedup_vs_measured(), 1.0);
        }
    }

    #[test]
    fn ideal_fabric_lower_bounds_real_fabrics() {
        let cluster = crate::cluster::presets::v100_cluster();
        let entry = entry_of(zoo::resnet50(), &cluster, 4, 4);
        let fw = fws::caffe_mpi();
        let ideal = predict_entry(&entry, &Fabric::Ideal, SchedulerKind::Fifo, &fw).unwrap();
        assert_eq!(ideal.comm_total_s, 0.0);
        for fabric in [
            Fabric::Measured,
            Fabric::Interconnect(Interconnect::TenGbE),
            Fabric::Interconnect(Interconnect::Ib100),
            Fabric::Cluster("k80-pcie-10gbe".into()),
            Fabric::alpha_beta(1e-4, 1e9).unwrap(),
        ] {
            let p = predict_entry(&entry, &fabric, SchedulerKind::Fifo, &fw).unwrap();
            assert!(
                ideal.replayed.iter_time_s <= p.replayed.iter_time_s + 1e-12,
                "ideal {} > {} on {}",
                ideal.replayed.iter_time_s,
                p.replayed.iter_time_s,
                fabric.name()
            );
        }
    }

    /// Swapping the 10 GbE cluster's measured workload onto the 100 Gb
    /// IB fabric must speed up the comm-bound job — the paper's central
    /// what-if, now answered from measurements.
    #[test]
    fn faster_fabric_speeds_up_comm_bound_entry() {
        let cluster = crate::cluster::presets::k80_cluster();
        let entry = entry_of(zoo::resnet50(), &cluster, 4, 4);
        let fw = fws::caffe_mpi();
        let fabric = Fabric::Interconnect(Interconnect::Ib100);
        let ib = predict_entry(&entry, &fabric, SchedulerKind::Fifo, &fw).unwrap();
        assert!(
            ib.speedup_vs_measured() > 1.0,
            "IB should beat measured 10GbE: {}x",
            ib.speedup_vs_measured()
        );
        assert!(ib.comm_total_s > 0.0);
    }

    #[test]
    fn autotune_fusion_beats_layerwise_on_comm_bound_entry() {
        let cluster = crate::cluster::presets::v100_cluster();
        let entry = entry_of(zoo::resnet50(), &cluster, 4, 4);
        let fw = fws::caffe_mpi();
        let tune = autotune_fusion(&entry, &Fabric::Measured, &fw).unwrap();
        assert!(tune.buckets > 1, "optimum should fuse but not into one bucket");
        assert!(tune.cap_bytes >= 64.0 * 1024.0);
        assert!(
            tune.replayed_iter_s < tune.layerwise_iter_s,
            "fused replay {} should beat layer-wise {}",
            tune.replayed_iter_s,
            tune.layerwise_iter_s
        );
        assert!(tune.gain_pct() > 0.0);
        // Single-rank entries cannot fuse.
        let solo = entry_of(zoo::googlenet(), &cluster, 1, 1);
        assert!(autotune_fusion(&solo, &Fabric::Measured, &fw).is_err());
    }

    #[test]
    fn scenarios_cross_entries_topologies_fabrics_schedulers() {
        let cluster = crate::cluster::presets::k80_cluster();
        let profile = profile_for(&cluster);
        let fabrics = [Fabric::Measured, Fabric::Ideal];
        let topologies = [None, Some(Topology::new(8, 4).unwrap())];
        let kinds = [SchedulerKind::Fifo, SchedulerKind::Priority];
        validate_whatif(&profile, &fabrics, &topologies).unwrap();
        let cells = scenarios(&profile, &fabrics, &topologies, &kinds);
        assert_eq!(cells.len(), 2 * 2 * 2 * 2);
        let mut keys: Vec<String> = cells.iter().map(|s| s.key()).collect();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), cells.len(), "axes must keep keys distinct");
        assert!(keys.iter().any(|k| k.contains("fabric=ideal")));
        assert!(keys.iter().any(|k| k.contains("topology=8x4")));
        assert!(keys.iter().all(|k| k.contains("profile=caffe-mpi#")));
        let outcome = runner::run_with(&cells, 2, None, |s| whatif_cell(&profile, s));
        for (s, r) in &outcome.cells {
            assert!(r.get("iter_time_s").unwrap() > 0.0, "{}", s.key());
            assert!(r.get("speedup_vs_measured").unwrap() > 0.0);
            if s.fabric.as_deref() == Some("ideal") {
                assert_eq!(r.get("comm_total_s"), Some(0.0));
                // No aggregation tasks are built at all on the ideal
                // fabric, so exposure is exactly zero, not epsilon.
                assert_eq!(r.get("comm_exposed_s"), Some(0.0), "{}", s.key());
                assert_eq!(r.get("comm_hidden_s"), Some(0.0), "{}", s.key());
            }
            if s.topology.as_deref() == Some("8x4") {
                assert_eq!(r.get("pred_gpus"), Some(32.0), "{}", s.key());
            }
        }
    }

    #[test]
    fn validate_whatif_gates_bad_fabrics_and_topologies() {
        let cluster = crate::cluster::presets::k80_cluster();
        let profile = profile_for(&cluster);
        assert!(validate_whatif(&profile, &[], &[None]).is_err());
        assert!(validate_whatif(&profile, &[Fabric::Measured], &[]).is_err());
        // localhost has 1 node x 4 workers: the 4-node entry cannot fit.
        let err = validate_whatif(&profile, &[Fabric::Cluster("localhost-shm".into())], &[None])
            .unwrap_err();
        assert!(err.contains("do not fit"), "{err}");
        // Routed fabrics share the same strict fit gate.
        let routed_local = Fabric::parse("routed:localhost").unwrap();
        let err = validate_whatif(&profile, &[routed_local], &[None]).unwrap_err();
        assert!(err.contains("do not fit"), "{err}");
        // And a routed preset that fits validates across the axes.
        let routed = Fabric::parse("routed:k80:spine=2").unwrap();
        validate_whatif(&profile, &[routed], &[None, Some(Topology::new(8, 4).unwrap())])
            .unwrap();
        // The measured fabric is exempt from channel checks.
        validate_whatif(&profile, &[Fabric::Measured, Fabric::Ideal], &[None]).unwrap();
        // Topology gates run pre-sweep too: a single-GPU-measured entry
        // has no fitted channel, so it cannot rescale out — that must be
        // a clean validation error, not a worker panic.
        let solo = CalibratedProfile {
            framework: "caffe-mpi".into(),
            entries: vec![entry_of(zoo::googlenet(), &cluster, 1, 1)],
        };
        let err = validate_whatif(
            &solo,
            &[Fabric::Measured],
            &[Some(Topology::new(2, 4).unwrap())],
        )
        .unwrap_err();
        assert!(err.contains("no fitted comm channel"), "{err}");
    }

    #[test]
    fn topology_names_round_trip_and_validate() {
        for t in [Topology::new(1, 1).unwrap(), Topology::new(8, 4).unwrap()] {
            assert_eq!(Topology::parse(&t.name()).unwrap(), t);
        }
        assert_eq!(Topology::parse("2x4").unwrap().ranks(), 8);
        assert!(Topology::new(0, 4).is_err(), "zero nodes");
        assert!(Topology::new(4, 0).is_err(), "zero GPUs");
        assert!(Topology::parse("0x4").is_err());
        assert!(Topology::parse("4x").is_err());
        assert!(Topology::parse("16").is_err(), "missing separator");
        assert!(Topology::parse("1000x1000").is_err(), "rank cap");
    }

    /// The identity contract behind the bit-identity keystone: rescaling
    /// an entry to its own measured layout returns the entry unchanged,
    /// and the prediction collapses onto the plain-replay code path.
    #[test]
    fn rescale_to_measured_scale_is_identity() {
        let cluster = crate::cluster::presets::k80_cluster();
        let entry = entry_of(zoo::alexnet(), &cluster, 2, 4);
        let fw = fws::caffe_mpi();
        let measured = measured_topology(&entry).unwrap();
        assert_eq!(measured, Topology::new(2, 4).unwrap());
        let same = rescale_entry(&entry, measured, &fw).unwrap();
        assert_eq!(same, entry);
        let p = predict_entry_at(
            &entry,
            &Fabric::Measured,
            Some(measured),
            SchedulerKind::Fifo,
            &fw,
            None,
        )
        .unwrap();
        let r = replay::replay_entry(&entry, SchedulerKind::Fifo, &fw).unwrap();
        assert_eq!(p.replayed.iter_time_s.to_bits(), r.iter_time_s.to_bits());
        assert_eq!(p.topology, None, "identity target collapses");
        assert_eq!(p.pred_gpus, entry.gpus);
    }

    /// Scaling out re-prices every collective upward: the scaled fit's
    /// latency grows and bandwidth shrinks with the participant count,
    /// and the per-layer comm costs follow.
    #[test]
    fn rescale_reprices_collectives_with_scale() {
        let cluster = crate::cluster::presets::k80_cluster();
        let entry = entry_of(zoo::resnet50(), &cluster, 2, 4);
        let fw = fws::caffe_mpi();
        let at4 = rescale_entry(&entry, Topology::new(4, 4).unwrap(), &fw).unwrap();
        let at8 = rescale_entry(&entry, Topology::new(8, 4).unwrap(), &fw).unwrap();
        assert_eq!(at4.gpus, 16);
        assert_eq!(at8.gpus, 32);
        let (c2, c4, c8) = (entry.comm.unwrap(), at4.comm.unwrap(), at8.comm.unwrap());
        assert!(c4.alpha_s > c2.alpha_s, "latency grows with nodes");
        assert!(c8.alpha_s > c4.alpha_s);
        assert!(c4.bw_bps < c2.bw_bps, "effective bandwidth shrinks");
        assert!(c8.bw_bps < c4.bw_bps);
        assert_eq!(c8.overhead_s, c2.overhead_s, "framework overhead is kept");
        for ((l2, l4), l8) in entry.layers.iter().zip(&at4.layers).zip(&at8.layers) {
            assert_eq!(l2.fwd_s.to_bits(), l4.fwd_s.to_bits(), "compute is kept");
            assert_eq!(l2.bwd_s.to_bits(), l8.bwd_s.to_bits());
            if l2.size_bytes > 0 {
                assert!(l8.comm_s > l4.comm_s, "{}: comm must grow", l2.name);
            }
        }
        // Scaling down to one rank drops communication entirely.
        let solo = rescale_entry(&entry, Topology::new(1, 1).unwrap(), &fw).unwrap();
        assert!(solo.comm.is_none());
        assert!(solo.layers.iter().all(|l| l.comm_s == 0.0));
        // A single-GPU-measured entry has no channel to scale out with.
        let single = entry_of(zoo::googlenet(), &cluster, 1, 1);
        let err = rescale_entry(&single, Topology::new(2, 4).unwrap(), &fw).unwrap_err();
        assert!(err.contains("no fitted comm channel"), "{err}");
    }

    /// The fusion scheduling policy works on every what-if axis: its
    /// gang-launch cap is tuned against the channel actually scheduled
    /// (the fabric's, not blindly the fitted one) and the prediction
    /// simulates cleanly across fabrics × topologies — including the
    /// ideal channel, where every cap ties and fusing is free.
    #[test]
    fn fusion_policy_predictions_cover_every_axis() {
        let cluster = crate::cluster::presets::k80_cluster();
        let entry = entry_of(zoo::resnet50(), &cluster, 2, 4);
        let fw = fws::caffe_mpi();
        for fabric in [
            Fabric::Measured,
            Fabric::Interconnect(Interconnect::TenGbE),
            Fabric::alpha_beta(5e-3, 1e8).unwrap(), // drastically slower channel
            Fabric::Ideal,
        ] {
            for topo in [None, Some(Topology::new(4, 4).unwrap())] {
                let p = predict_entry_at(&entry, &fabric, topo, SchedulerKind::Fusion, &fw, None)
                    .unwrap_or_else(|e| panic!("{} at {:?}: {e}", fabric.name(), topo));
                assert!(
                    p.replayed.iter_time_s > 0.0 && p.replayed.iter_time_s.is_finite(),
                    "{} at {:?}",
                    fabric.name(),
                    topo
                );
            }
        }
    }

    /// The portfolio autotuner races every registered policy and keeps
    /// the winner's prediction untouched: bit-identical to the best
    /// solo prediction, with the winner named on the result.
    #[test]
    fn portfolio_prediction_is_bit_identical_to_best_solo_policy() {
        let cluster = crate::cluster::presets::k80_cluster();
        let entry = entry_of(zoo::resnet50(), &cluster, 2, 4);
        let fw = fws::caffe_mpi();
        let fabric = Fabric::Interconnect(Interconnect::TenGbE);
        let (p, _) =
            predict_sim_at(&entry, &fabric, None, SchedulerKind::Portfolio, &fw, None).unwrap();
        assert!(!p.scheduler.is_portfolio(), "the race must name a concrete winner");
        let mut best: Option<Prediction> = None;
        for k in SchedulerKind::all() {
            let solo = predict_entry(&entry, &fabric, k, &fw).unwrap();
            let better = match &best {
                None => true,
                Some(b) => solo.replayed.iter_time_s < b.replayed.iter_time_s,
            };
            if better {
                best = Some(solo);
            }
        }
        let best = best.unwrap();
        assert_eq!(p.scheduler, best.scheduler, "registry order breaks ties");
        assert_eq!(p.replayed.iter_time_s.to_bits(), best.replayed.iter_time_s.to_bits());
        assert_eq!(p.replayed.makespan_s.to_bits(), best.replayed.makespan_s.to_bits());
        assert_eq!(p.measured_iter_s.to_bits(), best.measured_iter_s.to_bits());
    }

    /// The bound and portfolio columns end to end at the what-if level:
    /// every cell carries `lower_bound_s`/`gap_to_bound`, no cell beats
    /// its bound, the portfolio cell is bit-identical to the winning
    /// solo cell, and the winner rides the rows into the v3 report.
    #[test]
    fn whatif_cells_carry_bounds_and_portfolio_winner() {
        let cluster = crate::cluster::presets::k80_cluster();
        let profile = CalibratedProfile {
            framework: "caffe-mpi".into(),
            entries: vec![entry_of(zoo::alexnet(), &cluster, 2, 4)],
        };
        let fabrics = [Fabric::Measured, Fabric::Ideal];
        let mut kinds = vec![SchedulerKind::Portfolio];
        kinds.extend(SchedulerKind::all());
        let cells = scenarios(&profile, &fabrics, &[None], &kinds);
        let baselines = measured_baselines(&profile, &cells).unwrap();
        let outcome =
            runner::run_with(&cells, 2, None, |s| whatif_cell_with(&profile, s, &baselines));
        for (s, r) in &outcome.cells {
            let bound = r.get("lower_bound_s").expect("every cell carries the bound");
            let gap = r.get("gap_to_bound").expect("every cell carries the gap");
            assert!(bound > 0.0, "{}", s.key());
            assert!(gap >= 0.0, "{}", s.key());
            assert!(r.get("makespan_s").unwrap() >= bound - 1e-9, "{}", s.key());
            if !s.scheduler.is_portfolio() {
                assert_eq!(r.get("portfolio_winner_code"), None, "{}", s.key());
            }
        }
        for fabric in ["measured", "ideal"] {
            let cell = |kind: SchedulerKind| {
                outcome
                    .cells
                    .iter()
                    .find(|(s, _)| s.fabric.as_deref() == Some(fabric) && s.scheduler == kind)
                    .map(|(_, r)| r)
                    .unwrap()
            };
            let pf = cell(SchedulerKind::Portfolio);
            let code = pf.get("portfolio_winner_code").expect("portfolio cells name a winner");
            let winner = SchedulerKind::from_index(code as usize).expect("registered winner");
            let solo = cell(winner);
            for k in
                ["iter_time_s", "makespan_s", "lower_bound_s", "gap_to_bound", "measured_iter_s"]
            {
                assert_eq!(
                    pf.get(k).unwrap().to_bits(),
                    solo.get(k).unwrap().to_bits(),
                    "{fabric}/{k}: the portfolio must keep the winner's bits"
                );
            }
            for k in SchedulerKind::all() {
                assert!(
                    pf.get("iter_time_s").unwrap() <= cell(k).get("iter_time_s").unwrap(),
                    "{fabric}: no solo policy may beat the portfolio"
                );
            }
        }
        let rows =
            rows(&profile, &fabrics, &[None], &[SchedulerKind::Portfolio], false, 2).unwrap();
        assert!(rows.iter().all(|r| r.portfolio_winner.is_some()));
        assert!(rows
            .iter()
            .all(|r| r.lower_bound_s.unwrap() > 0.0 && r.gap_to_bound.unwrap() >= 0.0));
        let table = render(&rows);
        assert!(table.contains("portfolio→"), "{table}");
        let report = report_to_json(&rows, &profile.framework, &profile.tag());
        let text = report.to_string();
        assert!(text.contains("\"portfolio_winner\":\""), "{text}");
        assert!(text.contains("\"lower_bound_s\":"), "{text}");
        let back = json::parse(&text).unwrap();
        assert_eq!(validate_report(&back).unwrap(), rows.len());
    }

    #[test]
    fn report_roundtrips_and_validator_rejects_tampering() {
        let cluster = crate::cluster::presets::k80_cluster();
        let profile = profile_for(&cluster);
        let fabrics = [Fabric::Measured, Fabric::Interconnect(Interconnect::Ib100)];
        let topologies = [None, Some(Topology::new(8, 4).unwrap())];
        let rows = rows(&profile, &fabrics, &topologies, &[SchedulerKind::Fifo], true, 2).unwrap();
        assert_eq!(rows.len(), 2 * 2 * 2);
        assert!(
            rows.iter().any(|r| r.fusion.is_some()),
            "multi-rank entries should autotune"
        );
        let table = render(&rows);
        assert!(table.contains("ib") || table.contains("100gb-ib"));
        assert!(table.contains("8x4"), "predicted scale column:\n{table}");

        let good = report_to_json(&rows, &profile.framework, &profile.tag());
        let text = good.to_string();
        let back = json::parse(&text).unwrap();
        assert_eq!(validate_report(&back).unwrap(), rows.len());
        let check = |s: &str| validate_report(&json::parse(s).unwrap());
        assert!(check(&text.replace("\"schema_version\":3", "\"schema_version\":4")).is_err());
        // v2 reports (no bound/portfolio columns) still validate.
        assert!(check(&text.replace("\"schema_version\":3", "\"schema_version\":2")).is_ok());
        assert!(check(&text.replace("\"bench\":\"whatif\"", "\"bench\":\"other\"")).is_err());
        assert!(check(&text.replace("\"rows\":[", "\"cells\":[")).is_err());
        assert!(check(&text.replace("\"topology\":", "\"layout\":")).is_err());
        assert!(check("{\"schema_version\":3,\"bench\":\"whatif\"}").is_err());
        // Bound and winner tampering is caught: negative gaps and
        // unregistered winners must not validate.
        assert!(check(&text.replace("\"gap_to_bound\":", "\"gap_to_bound\":-1,\"x\":")).is_err());
        assert!(check(
            &text.replace("\"portfolio_winner\":null", "\"portfolio_winner\":\"warp\"")
        )
        .is_err());
        assert!(check(
            &text.replace("\"portfolio_winner\":null", "\"portfolio_winner\":\"portfolio\"")
        )
        .is_err());

        // Fresh rows always carry the obs breakdown: the explain
        // section rides the report, renders, and tampering is caught.
        assert!(rows.iter().all(|r| r.explain.is_some()));
        let etable = render_explain(&rows);
        assert!(etable.contains("bottleneck"), "{etable}");
        assert!(etable.contains("-bound"), "{etable}");
        // Keys serialize sorted, so the explain section reads
        // {"rows":[...],"schema_version":1} and its version tag is the
        // only "schema_version":1} in the document.
        assert!(text.contains("\"explain\":{\"rows\":["), "{text}");
        assert!(check(&text.replace("\"schema_version\":1}", "\"schema_version\":9}")).is_err());
        assert!(check(&text.replace("\"bottleneck\":\"", "\"bottleneck\":\"x")).is_err());
    }

    /// Routed rows carry the per-link utilization ledger end to end:
    /// computed at assembly, named in the explain table's hot-link
    /// column, serialized in the report, and schema-checked.
    #[test]
    fn routed_links_ride_rows_and_report() {
        let cluster = crate::cluster::presets::k80_cluster();
        let profile = profile_for(&cluster);
        let fabrics = [Fabric::Measured, Fabric::parse("routed:k80:spine=2").unwrap()];
        let topologies = [None, Some(Topology::new(8, 4).unwrap())];
        let rows =
            rows(&profile, &fabrics, &topologies, &[SchedulerKind::Fifo], false, 2).unwrap();
        assert_eq!(rows.len(), 2 * 2 * 2);
        for r in &rows {
            if r.fabric.starts_with("routed:") {
                let links = r.links.as_ref().expect("routed multi-node rows carry links");
                assert!(!links.is_empty());
                assert!(links.iter().all(|l| l.utilization > 0.0 && l.utilization <= 1.0));
                assert!(links.iter().any(|l| l.label == "spine-backplane"));
            } else {
                assert!(r.links.is_none(), "{}: flat fabrics have no link ledger", r.fabric);
            }
        }
        // Laddered past the 2-flow spine budget, the verdict names it.
        let wide = rows
            .iter()
            .find(|r| r.fabric.starts_with("routed:") && r.topology == "8x4")
            .unwrap();
        let hot = breakdown::saturated_link(wide.links.as_deref().unwrap()).unwrap();
        assert_eq!(hot.label, "spine-backplane");
        let etable = render_explain(&rows);
        assert!(etable.contains("hot link"), "{etable}");
        assert!(etable.contains("spine-backplane saturated"), "{etable}");

        let report = report_to_json(&rows, &profile.framework, &profile.tag());
        let text = report.to_string();
        assert!(text.contains("\"links\":[{"), "{text}");
        assert!(text.contains("spine-backplane"), "{text}");
        let back = json::parse(&text).unwrap();
        assert_eq!(validate_report(&back).unwrap(), rows.len());
        let check = |s: &str| validate_report(&json::parse(s).unwrap());
        assert!(check(&text.replace("\"link\":", "\"lnk\":")).is_err());
        assert!(check(&text.replace("\"utilization\":0.", "\"utilization\":-0.")).is_err());
    }
}
