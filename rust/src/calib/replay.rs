//! Trace replay: execute a calibrated job through the DAG simulator.
//!
//! This is the paper's Table V workflow with the testbed swapped for the
//! discrete-event engine: take *measured* per-layer forward/backward/
//! all-reduce times (a [`NetCalibration`]), rebuild the S-SGD DAG with
//! those durations via [`builder::build_with`] (the h2d copy and the
//! optimizer step come from the hardware model — the trace does not
//! record them, exactly like the published files), and simulate it under
//! any [`SchedulerKind`]. The closed-form WFBP estimate of the same
//! numbers ([`traced_iter_time`]) plays the role of the paper's
//! measurement column; `calib::validate` turns the pair into the
//! prediction-error report.
//!
//! Replay cells are ordinary campaign scenarios (profile-tagged, content
//! hashed) so profile-driven sweeps flow through the shared runner,
//! cache and report plumbing — the `calib` campaign axis.

use super::fit::{split_ranks, CalibratedProfile, NetCalibration};
use crate::analytic::{eqs, fusion};
use crate::campaign::grid::{CellResult, Interconnect, Scenario};
use crate::cluster::presets;
use crate::cluster::topology::{ClusterResources, ClusterSpec};
use crate::coordinator::metrics::PhaseTotals;
use crate::dag::builder::{self, Durations, JobSpec};
use crate::dag::graph::Dag;
use crate::frameworks::strategy::{self, Strategy};
use crate::models::perf::PerfModel;
use crate::models::zoo;
use crate::obs::breakdown;
use crate::sim::executor::{self, SimResult};
use crate::sim::lower_bound;
use crate::sim::scheduler::SchedulerKind;

/// One replayed job.
#[derive(Clone, Debug)]
pub struct Replayed {
    /// Steady-state iteration time of the replayed DAG, seconds.
    pub iter_time_s: f64,
    /// Whole-run makespan of the replayed DAG, seconds.
    pub makespan_s: f64,
    pub samples_per_s: f64,
    pub tasks: usize,
}

/// Iterations simulated per replay (matches
/// [`builder::iteration_time`]'s minimum: warmup 2 + measured tail).
pub const REPLAY_ITERS: usize = 8;

/// Rebuild [`Durations`] from the calibration entry: measured I/O,
/// forward, backward and comm; modeled h2d and update (absent from the
/// trace format). Decode time is 0 — the Table VI convention folds any
/// CPU decode into the data row, which replay accounts to the I/O stage.
pub fn durations_from(
    entry: &NetCalibration,
    job: &JobSpec,
    pm: &PerfModel,
    h2d: f64,
) -> Durations {
    let mut fwd = vec![0.0; entry.layers.len()];
    let mut bwd = vec![0.0; entry.layers.len()];
    let mut comm = vec![0.0; entry.layers.len()];
    for (i, (spec, cal)) in job.net.layers.iter().zip(&entry.layers).enumerate() {
        if spec.kind == crate::models::layer::LayerKind::Data {
            continue; // the data row is entry.t_io_s, not GPU work
        }
        fwd[i] = cal.fwd_s;
        bwd[i] = cal.bwd_s;
        comm[i] = cal.comm_s;
    }
    Durations {
        io: entry.t_io_s,
        decode: 0.0,
        h2d,
        fwd,
        bwd,
        comm,
        update: pm.update_time(&job.net),
    }
}

/// Resolve an entry back into simulator specs (shared with the what-if
/// engine, which keeps the measured compute side of the job and swaps
/// only the collective channel).
pub(crate) fn resolve(entry: &NetCalibration) -> Result<(ClusterSpec, JobSpec), String> {
    resolve_at(entry, None)
}

/// [`resolve`] with an optional hypothetical-topology override — the
/// what-if engine's scale-out axis. `Some((nodes, gpus_per_node))`
/// places the entry's per-GPU workload on that many nodes/GPUs of the
/// *same* per-node hardware, enlarging the preset cluster's extent when
/// the target exceeds it (predicting a job bigger than the measured
/// testbed is the point of a scale-out what-if; per-node link and GPU
/// parameters are untouched). The entry's GPU count must equal the
/// target's rank count — rescaled entries are synthesized to match.
/// `None` keeps the strict measured-layout resolution, which rejects
/// counts the physical cluster cannot host.
pub(crate) fn resolve_at(
    entry: &NetCalibration,
    at: Option<(usize, usize)>,
) -> Result<(ClusterSpec, JobSpec), String> {
    let mut cluster = presets::by_name(&entry.cluster)
        .ok_or_else(|| format!("unknown cluster '{}' in profile", entry.cluster))?;
    let net = zoo::by_name(&entry.net)
        .ok_or_else(|| format!("unknown net '{}' in profile", entry.net))?;
    if net.layers.len() != entry.layers.len() {
        return Err(format!(
            "profile entry has {} layers but {} has {}",
            entry.layers.len(),
            net.name,
            net.layers.len()
        ));
    }
    let (nodes, gpus_per_node) = match at {
        None => split_ranks(&cluster, entry.gpus)?,
        Some((nodes, gpus_per_node)) => {
            if nodes == 0 || gpus_per_node == 0 {
                return Err(format!("topology {nodes}x{gpus_per_node} has no GPUs"));
            }
            if nodes * gpus_per_node != entry.gpus {
                return Err(format!(
                    "entry has {} GPUs but topology {nodes}x{gpus_per_node} has {}",
                    entry.gpus,
                    nodes * gpus_per_node
                ));
            }
            cluster.nodes = cluster.nodes.max(nodes);
            cluster.gpus_per_node = cluster.gpus_per_node.max(gpus_per_node);
            (nodes, gpus_per_node)
        }
    };
    let batch = if entry.batch > 0 { entry.batch } else { net.default_batch };
    let job = JobSpec {
        batch_per_gpu: batch,
        net,
        nodes,
        gpus_per_node,
        iterations: REPLAY_ITERS,
    };
    Ok((cluster, job))
}

/// Replay one calibration entry under a scheduling policy. `fw` supplies
/// the overlap strategy (prefetch/pre-stage/WFBP edges of the DAG); the
/// per-task durations come from the measurement.
pub fn replay_entry(
    entry: &NetCalibration,
    kind: SchedulerKind,
    fw: &Strategy,
) -> Result<Replayed, String> {
    replay_entry_with_comm(entry, kind, fw, None)
}

/// [`replay_entry`] with an optionally substituted per-layer collective
/// cost vector (forward layer order, one slot per trace row) — the
/// what-if engine's door into the replay pipeline. `None` replays the
/// measured comm exactly; the two calls are the *same* code path, so a
/// what-if prediction on the measured fabric is bit-identical to plain
/// replay by construction.
pub fn replay_entry_with_comm(
    entry: &NetCalibration,
    kind: SchedulerKind,
    fw: &Strategy,
    comm: Option<&[f64]>,
) -> Result<Replayed, String> {
    replay_entry_with_comm_at(entry, kind, fw, comm, None)
}

/// [`replay_entry_with_comm`] at an optional hypothetical topology
/// (`(nodes, gpus_per_node)`, see [`resolve_at`]) — the scale-out
/// door: the what-if engine rescales an entry to a different node/GPU
/// count and replays it here, so I/O contention (the resource structure
/// behind `ClusterSpec::io_sharing`), prefetch pipelines and collective
/// serialization are all re-simulated at the *predicted* scale. `None`
/// is the exact measured-layout code path.
pub fn replay_entry_with_comm_at(
    entry: &NetCalibration,
    kind: SchedulerKind,
    fw: &Strategy,
    comm: Option<&[f64]>,
    at: Option<(usize, usize)>,
) -> Result<Replayed, String> {
    replay_entry_with_comm_capped(entry, kind, fw, comm, at, None)
}

/// [`replay_entry_with_comm_at`] with an explicit fusion bucket cap for
/// [`SchedulerKind::Fusion`]'s gang-launch policy. `None` autotunes the
/// cap against the entry's *fitted* channel (the measured optimum —
/// right for measured-fabric replays); the what-if engine passes the
/// cap scanned against the *fabric being predicted* when it substitutes
/// a hypothetical channel, so the policy is tuned for the comm costs it
/// actually schedules. Non-fusion policies ignore the cap.
pub fn replay_entry_with_comm_capped(
    entry: &NetCalibration,
    kind: SchedulerKind,
    fw: &Strategy,
    comm: Option<&[f64]>,
    at: Option<(usize, usize)>,
    cap_override: Option<f64>,
) -> Result<Replayed, String> {
    Ok(replay_sim_with_comm_capped(entry, kind, fw, comm, at, cap_override)?.replayed)
}

/// A replay with its simulation artifacts retained: the stamped DAG,
/// the resource layout it ran on, and the scheduled timeline — exactly
/// the inputs [`crate::obs::breakdown`] explains a prediction from.
/// [`replay_entry_with_comm_capped`] is this with the artifacts dropped.
pub struct ReplaySim {
    pub replayed: Replayed,
    pub dag: Dag,
    pub res: ClusterResources,
    pub sim: SimResult,
}

impl ReplaySim {
    /// The per-phase/critical-path/exposed-comm decomposition of this
    /// replay's timeline.
    pub fn breakdown(&self) -> breakdown::Breakdown {
        breakdown::breakdown(&self.dag, &self.res.pool, &self.sim)
    }
}

/// [`replay_entry_with_comm_capped`], keeping the DAG, resources and
/// timeline alive for explanation/tracing instead of discarding them.
/// Same computation in the same order — `.replayed` is bit-identical to
/// what the plain entry points return.
pub fn replay_sim_with_comm_capped(
    entry: &NetCalibration,
    kind: SchedulerKind,
    fw: &Strategy,
    comm: Option<&[f64]>,
    at: Option<(usize, usize)>,
    cap_override: Option<f64>,
) -> Result<ReplaySim, String> {
    if kind.is_portfolio() {
        return Ok(portfolio_race(entry, fw, comm, at, cap_override)?.1);
    }
    let (cluster, job) = resolve_at(entry, at)?;
    let pm = PerfModel::for_cluster(&cluster);
    let h2d = (job.batch_per_gpu as u64 * job.net.input_bytes) as f64 / cluster.h2d_bw;
    let mut dur = durations_from(entry, &job, &pm, h2d);
    // The fusion policy's bucket cap: an explicit override wins; else
    // autotune against the *measured* durations and fitted channel (the
    // ROADMAP wiring), taken before any what-if comm override rewrites
    // `dur`. Non-fusion kinds skip the scan entirely.
    let fusion_cap = match (kind, cap_override) {
        (SchedulerKind::Fusion, Some(cap)) => Some(cap),
        (SchedulerKind::Fusion, None) => fusion_cap_with(entry, &cluster, &job, h2d, &dur),
        _ => None,
    };
    if let Some(comm) = comm {
        if comm.len() != dur.comm.len() {
            return Err(format!(
                "substituted comm vector has {} slots but {} has {} layers",
                comm.len(),
                entry.net,
                dur.comm.len()
            ));
        }
        for (i, spec) in job.net.layers.iter().enumerate() {
            if spec.kind != crate::models::layer::LayerKind::Data {
                dur.comm[i] = comm[i];
            }
        }
    }
    let res = cluster.build_resources(job.nodes, job.gpus_per_node);
    // Template-cached build: repeated replays of the same entry (what-if
    // sweeps, cap scans) re-stamp durations onto a cached CSR skeleton
    // instead of re-running the builder.
    let dag = builder::build_with_cached(&res, &job, fw, &dur);
    let mut sched = kind.build_with_fusion_cap(&job.net, fusion_cap);
    let sim = executor::simulate_with(&dag, &res.pool, sched.as_mut());
    let iter = executor::steady_state_from(&sim, &dag, job.iterations, 2);
    let replayed = Replayed {
        iter_time_s: iter,
        makespan_s: sim.makespan,
        samples_per_s: (job.ranks() * job.batch_per_gpu) as f64 / iter,
        tasks: dag.len(),
    };
    Ok(ReplaySim { replayed, dag, res, sim })
}

/// The `--scheduler portfolio` race: replay the entry under **every**
/// concrete registered policy and keep the fastest steady-state
/// iteration (ties break toward registry order, so the result is
/// deterministic). The winner's [`ReplaySim`] is byte-for-byte what the
/// same solo replay returns — the race *selects*, it never recomputes —
/// so a portfolio cell is bit-identical to the best individual policy's
/// cell by construction.
pub fn portfolio_race(
    entry: &NetCalibration,
    fw: &Strategy,
    comm: Option<&[f64]>,
    at: Option<(usize, usize)>,
    cap_override: Option<f64>,
) -> Result<(SchedulerKind, ReplaySim), String> {
    let mut best: Option<(SchedulerKind, ReplaySim)> = None;
    for kind in SchedulerKind::all() {
        let rs = replay_sim_with_comm_capped(entry, kind, fw, comm, at, cap_override)?;
        let better = match &best {
            None => true,
            Some((_, b)) => rs.replayed.iter_time_s < b.replayed.iter_time_s,
        };
        if better {
            best = Some((kind, rs));
        }
    }
    Ok(best.expect("the scheduler registry has at least one concrete policy"))
}

/// The measurement-driven fusion bucket cap for an entry: the optimum of
/// `analytic::fusion`'s scan run against the entry's *fitted* α–β
/// channel over its measured gradient stream (the ROADMAP item — `sched`-
/// style comparisons on calibrated profiles run at the measured optimum,
/// not the 25 MiB default). `None` when the entry has no comm fit or
/// records no gradient sizes; callers fall back to the default cap.
pub fn fusion_cap_for(
    entry: &NetCalibration,
    cluster: &ClusterSpec,
    job: &JobSpec,
) -> Option<f64> {
    let pm = PerfModel::for_cluster(cluster);
    let h2d = (job.batch_per_gpu as u64 * job.net.input_bytes) as f64 / cluster.h2d_bw;
    let dur = durations_from(entry, job, &pm, h2d);
    fusion_cap_with(entry, cluster, job, h2d, &dur)
}

/// WFBP iteration inputs of an entry over the given per-layer
/// collective costs — the single assembly the fusion-cap scans share
/// (replay's fitted-channel fallback and the what-if engine's
/// fabric-channel scans), so the `io_sharing` term and friends can
/// never silently diverge between them.
pub(crate) fn scan_iter_inputs(
    entry: &NetCalibration,
    cluster: &ClusterSpec,
    job: &JobSpec,
    h2d: f64,
    dur: &Durations,
    comm: Vec<f64>,
) -> eqs::IterInputs {
    eqs::IterInputs {
        t_io: entry.t_io_s * cluster.io_sharing(job.nodes, job.gpus_per_node),
        t_h2d: h2d,
        fwd: dur.fwd.clone(),
        bwd: dur.bwd.clone(),
        comm,
        t_u: dur.update,
    }
}

/// [`fusion_cap_for`] over already-assembled measured durations (the
/// replay path computes them anyway; don't rebuild them per cell).
fn fusion_cap_with(
    entry: &NetCalibration,
    cluster: &ClusterSpec,
    job: &JobSpec,
    h2d: f64,
    dur: &Durations,
) -> Option<f64> {
    let cal = entry.calibrated_comm()?;
    let bytes: Vec<f64> = entry.layers.iter().map(|l| l.size_bytes as f64).collect();
    let inputs = scan_iter_inputs(entry, cluster, job, h2d, dur, dur.comm.clone());
    fusion::autotuned_cap(&inputs, &bytes, &|b| cal.comm_time(b))
}

/// The closed-form iteration-time estimate of the *trace itself* (the
/// paper's "measured" column): Eq. 5's WFBP path over the mean layer
/// times, with the data-layer fetch scaled by the number of GPUs that
/// share a storage device (Eq. 6's `t_io_y` term, as in Fig. 4).
pub fn traced_iter_time(entry: &NetCalibration, fw: &Strategy) -> Result<f64, String> {
    let (cluster, job) = resolve(entry)?;
    let pm = PerfModel::for_cluster(&cluster);
    let h2d = (job.batch_per_gpu as u64 * job.net.input_bytes) as f64 / cluster.h2d_bw;
    let dur = durations_from(entry, &job, &pm, h2d);
    let inputs = eqs::IterInputs {
        t_io: entry.t_io_s * cluster.io_sharing(job.nodes, job.gpus_per_node),
        t_h2d: h2d,
        fwd: dur.fwd,
        bwd: dur.bwd,
        comm: dur.comm,
        t_u: dur.update,
    };
    Ok(eqs::iter_time(&inputs, fw.prefetch_io, fw.wfbp))
}

/// The trace's own per-phase totals for one steady-state iteration on
/// one rank — the *measured* side of the calibrate report's phase
/// table. I/O is scaled by the storage-sharing factor exactly as
/// [`traced_iter_time`] scales it, and the whole-iteration figure *is*
/// the traced estimate, so the table's `iter` sub-row reproduces the
/// Table V measured column.
pub fn measured_phase_totals(
    entry: &NetCalibration,
    fw: &Strategy,
) -> Result<PhaseTotals, String> {
    let (cluster, job) = resolve(entry)?;
    let pm = PerfModel::for_cluster(&cluster);
    let h2d = (job.batch_per_gpu as u64 * job.net.input_bytes) as f64 / cluster.h2d_bw;
    let dur = durations_from(entry, &job, &pm, h2d);
    Ok(PhaseTotals {
        io_wait: entry.t_io_s * cluster.io_sharing(job.nodes, job.gpus_per_node) + h2d,
        execute: dur.fwd.iter().sum::<f64>() + dur.bwd.iter().sum::<f64>(),
        comm: dur.comm.iter().sum(),
        update: dur.update,
        iter: traced_iter_time(entry, fw)?,
    })
}

/// Measured-vs-predicted phase totals for one entry: the trace's own
/// per-phase sums next to the replayed DAG's [`crate::obs::breakdown`]
/// totals, normalized to one steady-state iteration on one rank so the
/// two sides are unit-compatible (the simulated totals span all ranks
/// and all [`REPLAY_ITERS`] iterations; collectives span ranks by
/// construction, so `comm` divides by iterations only). Per-phase gaps
/// are expected and are the point of the diagnostic — overlap and
/// contention move simulated time between phases while the measured
/// side counts raw durations.
pub fn phase_comparison(
    entry: &NetCalibration,
    kind: SchedulerKind,
    fw: &Strategy,
) -> Result<(PhaseTotals, PhaseTotals), String> {
    let measured = measured_phase_totals(entry, fw)?;
    let rs = replay_sim_with_comm_capped(entry, kind, fw, None, None, None)?;
    let totals = rs.breakdown().phase_totals();
    let ranks = entry.gpus.max(1) as f64;
    let iters = REPLAY_ITERS as f64;
    let predicted = PhaseTotals {
        io_wait: totals.io_wait / (ranks * iters),
        execute: totals.execute / (ranks * iters),
        comm: totals.comm / iters,
        update: totals.update / (ranks * iters),
        iter: rs.replayed.iter_time_s,
    };
    Ok((measured, predicted))
}

/// One scored calibration entry: the DAG replay, the closed-form traced
/// estimate, and their percent error — the Table V triple every report
/// row is built from.
#[derive(Clone, Debug)]
pub struct Scored {
    pub replayed: Replayed,
    pub traced_iter_s: f64,
    pub error_pct: f64,
}

/// Replay an entry under `kind` and score it against the closed-form
/// traced estimate (the single definition of the prediction-error
/// metric used by `replay_cell`, `validate::prediction_rows` and the
/// Table V experiment).
pub fn score_entry(
    entry: &NetCalibration,
    kind: SchedulerKind,
    fw: &Strategy,
) -> Result<Scored, String> {
    let replayed = replay_entry(entry, kind, fw)?;
    let traced = traced_iter_time(entry, fw)?;
    Ok(Scored {
        error_pct: 100.0 * ((replayed.iter_time_s - traced) / traced).abs(),
        replayed,
        traced_iter_s: traced,
    })
}

/// The profile content hash is carried in `Scenario::seed`, masked to
/// 53 bits so it survives the report's f64 serialization exactly (the
/// full 64-bit hash lives in the `profile` tag of every cell key).
pub const PROFILE_SEED_MASK: u64 = (1 << 53) - 1;

/// Check a profile is sweepable before spawning workers: every entry
/// must resolve to simulator specs, entry addresses (net × cluster ×
/// GPUs × batch — the campaign cell identity) must be unique, and the
/// framework must be known. `campaign --profile` runs this up front so
/// a hand-edited profile fails with a clean error, not a worker panic.
pub fn validate_profile(profile: &CalibratedProfile) -> Result<(), String> {
    strategy::by_name(&profile.framework)
        .ok_or_else(|| format!("unknown framework '{}' in profile", profile.framework))?;
    let mut seen = std::collections::BTreeSet::new();
    for entry in &profile.entries {
        resolve(entry).map_err(|e| format!("{}: {e}", entry.key()))?;
        if !seen.insert(entry.key()) {
            return Err(format!(
                "duplicate profile entry '{}' (campaign cells are keyed by it)",
                entry.key()
            ));
        }
    }
    Ok(())
}

/// Campaign scenarios for a profile: one cell per entry × scheduler,
/// tagged with the profile's content hash so cache entries are
/// content-addressed (editing the profile file re-simulates). Callers
/// sweep only [`validate_profile`]-clean profiles; for unresolvable
/// entries the topology here is a display-only fallback.
pub fn scenarios(profile: &CalibratedProfile, kinds: &[SchedulerKind]) -> Vec<Scenario> {
    let tag = profile.tag();
    let seed = profile.content_hash() & PROFILE_SEED_MASK;
    let mut out = Vec::with_capacity(profile.entries.len() * kinds.len());
    for entry in &profile.entries {
        let topo = presets::by_name(&entry.cluster)
            .map(|c| split_ranks(&c, entry.gpus))
            .and_then(|r| r.ok())
            .unwrap_or((1, entry.gpus.max(1)));
        for &scheduler in kinds {
            out.push(Scenario {
                cluster: entry.cluster.clone(),
                interconnect: Interconnect::Stock,
                net: entry.net.clone(),
                framework: profile.framework.clone(),
                nodes: topo.0,
                gpus_per_node: topo.1,
                batch_per_gpu: Some(entry.batch),
                iterations: REPLAY_ITERS,
                scheduler,
                layerwise_update: false,
                seed,
                profile: Some(tag.clone()),
                fabric: None,
                topology: None,
            });
        }
    }
    out
}

/// The profile entry a campaign scenario addresses (net × cluster ×
/// GPU count × batch — the single definition of the cell identity
/// [`scenarios`] encodes; the what-if axis reuses it).
pub fn entry_for<'a>(
    profile: &'a CalibratedProfile,
    s: &Scenario,
) -> Option<&'a NetCalibration> {
    profile.entries.iter().find(|e| {
        e.net == s.net
            && e.cluster == s.cluster
            && e.gpus == s.nodes * s.gpus_per_node
            && Some(e.batch) == s.batch_per_gpu
    })
}

/// The per-cell measurement for profile-driven sweeps: replay the
/// matching entry under the cell's scheduler and attach the closed-form
/// traced estimate + prediction error, the makespan lower bound and
/// gap-to-bound, plus the obs breakdown metrics (per-phase totals,
/// critical-path split, exposed comm, bottleneck) so explained reports
/// serve straight from the cached cell. A `portfolio` cell races every
/// concrete policy and reports the winner's metrics unchanged, adding
/// `portfolio_winner_code` (the winner's registry index).
pub fn replay_cell(profile: &CalibratedProfile, s: &Scenario) -> CellResult {
    let fw = strategy::by_name(&profile.framework).expect("profile validated before sweep");
    let entry = entry_for(profile, s).expect("scenario was built from this profile");
    let (winner, rs) = if s.scheduler.is_portfolio() {
        let (w, rs) = portfolio_race(entry, &fw, None, None, None)
            .expect("profile validated before sweep");
        (Some(w), rs)
    } else {
        let rs = replay_sim_with_comm_capped(entry, s.scheduler, &fw, None, None, None)
            .expect("profile validated before sweep");
        (None, rs)
    };
    let traced = traced_iter_time(entry, &fw).expect("profile validated before sweep");
    let bound = lower_bound::makespan_lower_bound(&rs.dag, &rs.res.pool);
    let mut r = CellResult::new();
    r.set("iter_time_s", rs.replayed.iter_time_s)
        .set("samples_per_s", rs.replayed.samples_per_s)
        .set("makespan_s", rs.replayed.makespan_s)
        .set("traced_iter_s", traced)
        .set("error_pct", 100.0 * ((rs.replayed.iter_time_s - traced) / traced).abs())
        .set("lower_bound_s", bound)
        .set("gap_to_bound", lower_bound::gap_to_bound(rs.replayed.makespan_s, bound));
    if let Some(w) = winner {
        r.set("portfolio_winner_code", w.index() as f64);
    }
    for (k, v) in rs.breakdown().metric_pairs() {
        r.set(k, v);
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::fit::calibrate_one;
    use crate::campaign::runner;
    use crate::frameworks::strategy as fws;
    use crate::trace::synth::synth_trace;

    fn entry_of(
        net: crate::models::layer::NetSpec,
        nodes: usize,
        gpn: usize,
        iters: usize,
    ) -> NetCalibration {
        let cluster = presets::k80_cluster();
        let job = JobSpec {
            batch_per_gpu: net.default_batch,
            net,
            nodes,
            gpus_per_node: gpn,
            iterations: 1,
        };
        let t = synth_trace(&cluster, &job, &fws::caffe_mpi(), iters, 3);
        calibrate_one(&t, &fws::caffe_mpi()).unwrap()
    }

    fn entry(nodes: usize, gpn: usize, iters: usize) -> NetCalibration {
        entry_of(zoo::alexnet(), nodes, gpn, iters)
    }

    #[test]
    fn replay_close_to_model_simulation() {
        // The trace came from the model (plus jitter); replaying it must
        // land near the model's own simulation.
        let cluster = presets::k80_cluster();
        let net = zoo::alexnet();
        let job = JobSpec {
            batch_per_gpu: net.default_batch,
            net,
            nodes: 2,
            gpus_per_node: 4,
            iterations: REPLAY_ITERS,
        };
        let reference = builder::iteration_time(&cluster, &job, &fws::caffe_mpi());
        let e = entry(2, 4, 30);
        let replayed = replay_entry(&e, SchedulerKind::Fifo, &fws::caffe_mpi()).unwrap();
        assert!(
            (replayed.iter_time_s / reference - 1.0).abs() < 0.05,
            "replay {:.4}s vs model {:.4}s",
            replayed.iter_time_s,
            reference
        );
        assert!(replayed.makespan_s > replayed.iter_time_s);
        assert!(replayed.tasks > 0);
    }

    /// The closed-form traced estimate and the DAG replay are two
    /// different estimators of the same job; they must agree to the
    /// same order (Fig. 4 reports single-digit *mean* errors — a single
    /// whole-cluster cell can sit above that).
    #[test]
    fn traced_estimate_close_to_replay() {
        let e = entry(4, 4, 20);
        let fw = fws::caffe_mpi();
        let traced = traced_iter_time(&e, &fw).unwrap();
        let replayed = replay_entry(&e, SchedulerKind::Fifo, &fw).unwrap();
        let err = (replayed.iter_time_s - traced).abs() / traced;
        assert!(err < 0.25, "closed form {traced:.4}s vs DAG {:.4}s", replayed.iter_time_s);
    }

    /// Replay honors the scheduler axis: on the comm-bound headline job
    /// (multi-node ResNet-50 over 10 GbE, layer-wise updates) priority
    /// scheduling beats FIFO on replayed traces exactly as it does on
    /// model-derived DAGs (`experiments::sched`).
    #[test]
    fn schedulers_change_replay_like_the_model() {
        let e = entry_of(zoo::resnet50(), 4, 4, 10);
        let mut fw = fws::caffe_mpi();
        fw.layerwise_update = true;
        let fifo = replay_entry(&e, SchedulerKind::Fifo, &fw).unwrap();
        let prio = replay_entry(&e, SchedulerKind::Priority, &fw).unwrap();
        assert!(
            prio.iter_time_s < fifo.iter_time_s * 0.9999,
            "priority {:.4}s should beat fifo {:.4}s on replayed traces",
            prio.iter_time_s,
            fifo.iter_time_s
        );
    }

    #[test]
    fn scenarios_flow_through_the_campaign_runner() {
        let profile = CalibratedProfile {
            framework: "caffe-mpi".into(),
            entries: vec![entry(1, 2, 4), entry(2, 4, 4)],
        };
        let kinds = [SchedulerKind::Fifo, SchedulerKind::Priority];
        validate_profile(&profile).unwrap();
        let cells = scenarios(&profile, &kinds);
        assert_eq!(cells.len(), 4);
        for c in &cells {
            assert_eq!(c.profile.as_deref(), Some(profile.tag().as_str()));
            // 53-bit mask: the seed survives f64 report serialization.
            assert_eq!(c.seed, profile.content_hash() & PROFILE_SEED_MASK);
            assert_eq!(c.seed as f64 as u64, c.seed, "seed must be f64-exact");
            assert!(c.key().contains("profile=caffe-mpi#"), "{}", c.key());
        }
        let outcome = runner::run_with(&cells, 2, None, |s| replay_cell(&profile, s));
        assert_eq!(outcome.cells.len(), 4);
        for (s, r) in &outcome.cells {
            assert!(r.get("iter_time_s").unwrap() > 0.0, "{}", s.key());
            assert!(r.get("error_pct").unwrap().is_finite());
            // Every profile cell carries the obs breakdown metrics.
            assert!(r.get("comm_exposed_frac").unwrap().is_finite(), "{}", s.key());
            assert!(r.get("bottleneck_code").is_some(), "{}", s.key());
        }
    }

    /// The ROADMAP wiring: replaying a calibrated entry under
    /// `SchedulerKind::Fusion` gang-launches at the *measured* autotuned
    /// bucket cap, not the 25 MiB default. The wired cap is the scan
    /// optimum of the fitted channel, it differs from the default (the
    /// scan grid is 64 KiB doublings, which never hit 25 MiB), and the
    /// replay is bit-identical to a hand-built fusion policy at that cap.
    #[test]
    fn fusion_replay_runs_at_the_autotuned_cap() {
        use crate::sim::scheduler::DEFAULT_FUSION_CAP_BYTES;

        let e = entry_of(zoo::resnet50(), 4, 4, 10);
        let fw = fws::caffe_mpi();
        let (cluster, job) = resolve(&e).unwrap();
        let cap = fusion_cap_for(&e, &cluster, &job).expect("multi-rank entry has a comm fit");
        assert_ne!(cap.to_bits(), DEFAULT_FUSION_CAP_BYTES.to_bits());

        // The wired cap is exactly the fitted-channel scan optimum.
        let cal = e.calibrated_comm().unwrap();
        let bytes: Vec<f64> = e.layers.iter().map(|l| l.size_bytes as f64).collect();
        let pm = PerfModel::for_cluster(&cluster);
        let h2d = (job.batch_per_gpu as u64 * job.net.input_bytes) as f64 / cluster.h2d_bw;
        let dur = durations_from(&e, &job, &pm, h2d);
        let inputs = eqs::IterInputs {
            t_io: e.t_io_s * cluster.io_sharing(job.nodes, job.gpus_per_node),
            t_h2d: h2d,
            fwd: dur.fwd.clone(),
            bwd: dur.bwd.clone(),
            comm: dur.comm.clone(),
            t_u: dur.update,
        };
        let (_, best) = fusion::optimal_bucket_bytes_with(&inputs, &bytes, &|b| cal.comm_time(b));
        assert_eq!(cap.to_bits(), best.cap_bytes.to_bits());

        // And the replay builds its policy at that cap: bit-identical to
        // simulating the same DAG under a hand-built fusion scheduler.
        let replayed = replay_entry(&e, SchedulerKind::Fusion, &fw).unwrap();
        let res = cluster.build_resources(job.nodes, job.gpus_per_node);
        let dag = builder::build_with(&res, &job, &fw, &dur);
        let mut hand = SchedulerKind::Fusion.build_with_fusion_cap(&job.net, Some(cap));
        let sim = crate::sim::executor::simulate_with(&dag, &res.pool, hand.as_mut());
        let iter = crate::sim::executor::steady_state_from(&sim, &dag, job.iterations, 2);
        assert_eq!(replayed.iter_time_s.to_bits(), iter.to_bits());
    }

    /// The phase-comparison diagnostic: both sides finite and positive
    /// where the job has work, and the `iter` sub-rows are exactly the
    /// replayed steady-state time and the traced estimate — the same
    /// numbers Table V scores.
    #[test]
    fn phase_comparison_sides_are_finite_and_positive() {
        let e = entry(2, 4, 10);
        let fw = fws::caffe_mpi();
        let (m, p) = phase_comparison(&e, SchedulerKind::Fifo, &fw).unwrap();
        for t in [&m, &p] {
            assert!(t.io_wait > 0.0 && t.execute > 0.0 && t.update > 0.0, "{t:?}");
            assert!(t.comm >= 0.0 && t.iter > 0.0, "{t:?}");
        }
        let replayed = replay_entry(&e, SchedulerKind::Fifo, &fw).unwrap();
        assert_eq!(p.iter.to_bits(), replayed.iter_time_s.to_bits());
        assert_eq!(m.iter.to_bits(), traced_iter_time(&e, &fw).unwrap().to_bits());
    }

    /// The portfolio acceptance triple: the race result is bit-identical
    /// to the winner's solo replay, no concrete policy beats it, and
    /// resolving `SchedulerKind::Portfolio` through the ordinary replay
    /// entry points lands on the same bits.
    #[test]
    fn portfolio_replay_is_bit_identical_to_best_solo_policy() {
        let e = entry_of(zoo::resnet50(), 4, 4, 10);
        let mut fw = fws::caffe_mpi();
        fw.layerwise_update = true;
        let (winner, rs) = portfolio_race(&e, &fw, None, None, None).unwrap();
        let solo = replay_entry(&e, winner, &fw).unwrap();
        assert_eq!(rs.replayed.iter_time_s.to_bits(), solo.iter_time_s.to_bits());
        assert_eq!(rs.replayed.makespan_s.to_bits(), solo.makespan_s.to_bits());
        for kind in SchedulerKind::all() {
            let r = replay_entry(&e, kind, &fw).unwrap();
            assert!(
                rs.replayed.iter_time_s <= r.iter_time_s,
                "{} ({:.6}s) beats the portfolio ({:.6}s)",
                kind.name(),
                r.iter_time_s,
                rs.replayed.iter_time_s
            );
        }
        let via_kind = replay_entry(&e, SchedulerKind::Portfolio, &fw).unwrap();
        assert_eq!(via_kind.iter_time_s.to_bits(), solo.iter_time_s.to_bits());
    }

    /// Every replay cell carries the lower-bound columns, the bound is
    /// sound (no simulated makespan below it), and a portfolio cell's
    /// shared metrics match the winner's solo cell bit-for-bit while
    /// adding a decodable `portfolio_winner_code`.
    #[test]
    fn replay_cells_carry_lower_bound_and_portfolio_winner() {
        let profile = CalibratedProfile {
            framework: "caffe-mpi".into(),
            entries: vec![entry_of(zoo::resnet50(), 2, 4, 6)],
        };
        validate_profile(&profile).unwrap();
        let mut kinds = vec![SchedulerKind::Portfolio];
        kinds.extend(SchedulerKind::all());
        let cells = scenarios(&profile, &kinds);
        let results: Vec<(Scenario, CellResult)> =
            cells.iter().map(|s| (s.clone(), replay_cell(&profile, s))).collect();
        for (s, r) in &results {
            let bound = r.get("lower_bound_s").expect("every cell has the bound");
            let gap = r.get("gap_to_bound").expect("every cell has the gap");
            assert!(bound > 0.0, "{}", s.key());
            assert!(gap >= 0.0, "{}", s.key());
            assert!(r.get("makespan_s").unwrap() >= bound - 1e-12, "{}", s.key());
        }
        let (_, portfolio) = results
            .iter()
            .find(|(s, _)| s.scheduler.is_portfolio())
            .expect("portfolio cell swept");
        let code = portfolio.get("portfolio_winner_code").expect("winner reported");
        let winner = SchedulerKind::from_index(code as usize).expect("winner is registered");
        let (_, solo) = results
            .iter()
            .find(|(s, _)| s.scheduler == winner)
            .expect("winner swept solo too");
        for key in ["iter_time_s", "makespan_s", "lower_bound_s", "gap_to_bound"] {
            assert_eq!(
                portfolio.get(key).unwrap().to_bits(),
                solo.get(key).unwrap().to_bits(),
                "portfolio '{key}' must be the winner's bits"
            );
        }
        assert!(solo.get("portfolio_winner_code").is_none(), "solo cells carry no winner");
    }

    #[test]
    fn resolve_errors_are_reported() {
        let mut e = entry(1, 2, 2);
        e.cluster = "mars".into();
        assert!(replay_entry(&e, SchedulerKind::Fifo, &fws::caffe_mpi()).is_err());
        let mut e = entry(1, 2, 2);
        e.net = "vgg".into();
        assert!(traced_iter_time(&e, &fws::caffe_mpi()).is_err());
        let mut e = entry(1, 2, 2);
        e.gpus = 7;
        assert!(replay_entry(&e, SchedulerKind::Fifo, &fws::caffe_mpi()).is_err());
    }

    /// The pre-sweep gate `campaign --profile` relies on: schema-valid
    /// but unsweepable profiles (unknown names, impossible topologies,
    /// duplicate entry addresses) fail with a message, not a worker
    /// panic inside the pool.
    #[test]
    fn validate_profile_gates_bad_profiles() {
        let good = CalibratedProfile {
            framework: "caffe-mpi".into(),
            entries: vec![entry(1, 2, 2), entry(2, 4, 2)],
        };
        validate_profile(&good).unwrap();

        let mut p = good.clone();
        p.framework = "pytorch".into();
        assert!(validate_profile(&p).unwrap_err().contains("unknown framework"));

        let mut p = good.clone();
        p.entries[0].cluster = "mars".into();
        assert!(validate_profile(&p).unwrap_err().contains("unknown cluster"));

        let mut p = good.clone();
        p.entries[1].gpus = 7;
        assert!(validate_profile(&p).is_err(), "partial nodes rejected");

        let mut p = good.clone();
        p.entries[1] = p.entries[0].clone();
        assert!(validate_profile(&p).unwrap_err().contains("duplicate"));
    }
}
