//! Parameter fitting: turn ingested traces into calibrated simulator
//! inputs — the workflow of "Performance Modeling and Evaluation of
//! Distributed Deep Learning Frameworks on GPUs" (arXiv:1711.05979)
//! applied to our models.
//!
//! Three fits per trace, each landing in the subsystem that consumes it:
//!
//! * **per-layer compute** → [`crate::models::perf`]: layer-kind
//!   efficiency factors recovered by least squares over the measured
//!   forward times of compute-bound Conv/Fc layers
//!   ([`perf::fit_efficiency`]);
//! * **communication** → [`crate::comm::alpha_beta`]: an effective α–β
//!   channel fitted over (gradient size, all-reduce time) pairs
//!   ([`Link::fit`]);
//! * **framework overhead** → [`crate::frameworks::strategy`]: the
//!   fitted intercept's excess over the backend model's per-collective
//!   latency, installed as [`CalibratedComm`] on a [`Strategy`].
//!
//! The result is a serializable [`CalibratedProfile`]; `calib::replay`
//! drives the DAG simulator from it and `calib::validate` scores the
//! predictions against the trace.

use crate::campaign::cache::fnv1a64;
use crate::cluster::presets;
use crate::cluster::topology::ClusterSpec;
use crate::comm::alpha_beta::Link;
use crate::dag::builder::comm_topo;
use crate::frameworks::strategy::{CalibratedComm, Strategy};
use crate::models::layer::{LayerKind, NetSpec};
use crate::models::perf::{self, KERNEL_LAUNCH};
use crate::models::zoo;
use crate::trace::format::Trace;
use crate::util::json::Json;

/// Version of the profile file format; bump on any layout change.
pub const PROFILE_SCHEMA_VERSION: u64 = 1;

/// Mean measured costs of one layer, in seconds (the trace stores µs).
#[derive(Clone, Debug, PartialEq)]
pub struct LayerCal {
    pub id: usize,
    pub name: String,
    pub fwd_s: f64,
    pub bwd_s: f64,
    pub comm_s: f64,
    pub size_bytes: u64,
}

/// The fitted α–β + overhead decomposition of the gradient channel.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CommFit {
    /// Hardware-attributable per-collective latency, seconds.
    pub alpha_s: f64,
    /// Achieved all-reduce bandwidth over message size, bytes/s.
    pub bw_bps: f64,
    /// Framework overhead beyond the backend model, seconds.
    pub overhead_s: f64,
    /// Number of (size, time) measurements the fit used.
    pub samples: usize,
}

/// Everything calibrated from one trace (one net × cluster × GPUs ×
/// batch job).
#[derive(Clone, Debug, PartialEq)]
pub struct NetCalibration {
    pub net: String,
    /// Cluster preset name (resolvable via [`presets::by_name`]).
    pub cluster: String,
    pub gpus: usize,
    pub batch: usize,
    /// Iterations the source trace averaged over.
    pub iterations: usize,
    /// Mean data-layer fetch time (the Table VI `data` row), seconds.
    pub t_io_s: f64,
    /// Fitted Conv/Fc efficiencies (`None`: no compute-bound sample).
    pub eff_conv: Option<f64>,
    pub eff_fc: Option<f64>,
    /// Fitted gradient channel (`None`: single-GPU trace, or fewer than
    /// two distinct gradient sizes).
    pub comm: Option<CommFit>,
    /// Mean per-layer costs, forward order (row 0 is the data layer).
    pub layers: Vec<LayerCal>,
}

impl NetCalibration {
    /// Human-readable entry key (report rows, CLI tables).
    pub fn key(&self) -> String {
        format!("{} @ {} g{} b{}", self.net, self.cluster, self.gpus, self.batch)
    }

    /// The fitted comm model as a strategy override.
    pub fn calibrated_comm(&self) -> Option<CalibratedComm> {
        self.comm.map(|c| CalibratedComm {
            link: Link::new(c.alpha_s, c.bw_bps),
            overhead_s: c.overhead_s,
        })
    }

    /// Install the fitted comm model on a framework strategy, returning
    /// the calibrated strategy (the campaign `calib` axis runs these).
    pub fn apply_to(&self, fw: &Strategy) -> Strategy {
        let mut out = fw.clone();
        out.calibrated_comm = self.calibrated_comm().or(out.calibrated_comm);
        out
    }
}

/// A set of calibrations plus the framework they were measured under —
/// the serializable artifact `dagsgd calibrate --out` writes.
#[derive(Clone, Debug, PartialEq)]
pub struct CalibratedProfile {
    pub framework: String,
    pub entries: Vec<NetCalibration>,
}

/// Factor a flat GPU count into `(nodes, gpus_per_node)` on a cluster:
/// counts up to one node stay single-node; larger counts must fill
/// whole nodes (the paper's configurations all do).
pub fn split_ranks(cluster: &ClusterSpec, gpus: usize) -> Result<(usize, usize), String> {
    if gpus == 0 {
        return Err("trace reports 0 GPUs".into());
    }
    if gpus <= cluster.gpus_per_node {
        return Ok((1, gpus));
    }
    if gpus % cluster.gpus_per_node != 0 {
        return Err(format!(
            "{gpus} GPUs is not a whole number of {}-GPU nodes",
            cluster.gpus_per_node
        ));
    }
    let nodes = gpus / cluster.gpus_per_node;
    if nodes > cluster.nodes {
        return Err(format!(
            "{gpus} GPUs needs {nodes} nodes but cluster '{}' has {}",
            cluster.name, cluster.nodes
        ));
    }
    Ok((nodes, cluster.gpus_per_node))
}

/// Compute-bound filter: a layer's forward time carries efficiency
/// information only when neither the memory floor nor the kernel-launch
/// floor explains it.
fn compute_bound(t: f64, mem_floor: f64) -> bool {
    t > 1.3 * mem_floor && t > 2.0 * KERNEL_LAUNCH
}

/// Efficiency-fit samples for one layer kind: `(flops, seconds)` over
/// the compute-bound layers of that kind.
fn efficiency_samples(
    net: &NetSpec,
    layers: &[LayerCal],
    batch: usize,
    mem_bw: f64,
    kind: LayerKind,
) -> Vec<(f64, f64)> {
    net.layers
        .iter()
        .zip(layers)
        .filter(|(spec, _)| spec.kind == kind)
        .filter_map(|(spec, cal)| {
            let flops = 2.0 * spec.fwd_macs * batch as f64;
            let mem_floor = 2.0 * 4.0 * spec.act_elems * batch as f64 / mem_bw;
            if flops > 0.0 && compute_bound(cal.fwd_s, mem_floor) {
                Some((flops, cal.fwd_s))
            } else {
                None
            }
        })
        .collect()
}

/// Calibrate one trace against the framework it was measured under.
/// Errors when the trace names an unknown net or cluster, or its rows
/// don't line up with the net's layer list — calibration needs the
/// architecture numbers (MACs, activation sizes) behind each row.
pub fn calibrate_one(trace: &Trace, fw: &Strategy) -> Result<NetCalibration, String> {
    let net = zoo::by_name(&trace.net)
        .ok_or_else(|| format!("unknown net '{}' in trace", trace.net))?;
    let cluster = presets::by_name(&trace.cluster)
        .ok_or_else(|| format!("unknown cluster '{}' in trace", trace.cluster))?;
    let batch = if trace.batch > 0 { trace.batch } else { net.default_batch };
    let rows = trace.mean_rows();
    if rows.is_empty() {
        return Err("trace has no iterations".into());
    }
    if rows.len() != net.layers.len() {
        return Err(format!(
            "trace has {} rows but {} has {} layers",
            rows.len(),
            net.name,
            net.layers.len()
        ));
    }
    for (spec, row) in net.layers.iter().zip(&rows) {
        if spec.name != row.name {
            return Err(format!(
                "row {} is '{}' but {} expects '{}'",
                row.id, row.name, net.name, spec.name
            ));
        }
    }

    let layers: Vec<LayerCal> = rows
        .iter()
        .map(|r| LayerCal {
            id: r.id,
            name: r.name.clone(),
            fwd_s: r.forward_us * 1e-6,
            bwd_s: r.backward_us * 1e-6,
            comm_s: r.comm_us * 1e-6,
            size_bytes: r.size_bytes,
        })
        .collect();
    let t_io_s = net
        .layers
        .iter()
        .zip(&layers)
        .find(|(spec, _)| spec.kind == LayerKind::Data)
        .map(|(_, cal)| cal.fwd_s)
        .unwrap_or(0.0);

    let eff_conv = perf::fit_efficiency(
        &efficiency_samples(&net, &layers, batch, cluster.gpu.mem_bw, LayerKind::Conv),
        cluster.gpu.peak_flops,
    );
    let eff_fc = perf::fit_efficiency(
        &efficiency_samples(&net, &layers, batch, cluster.gpu.mem_bw, LayerKind::Fc),
        cluster.gpu.peak_flops,
    );

    // The GPU count must map onto the cluster whether or not a comm fit
    // succeeds — a comm-less trace with an infeasible count is just as
    // unreplayable as one with comm data.
    let (nodes, gpus_per_node) = split_ranks(&cluster, trace.gpus)?;

    // α–β over the measured all-reduces; the intercept's excess over the
    // backend model's per-collective latency is the framework overhead.
    let comm_points: Vec<(f64, f64)> = layers
        .iter()
        .filter(|l| l.comm_s > 0.0 && l.size_bytes > 0)
        .map(|l| (l.size_bytes as f64, l.comm_s))
        .collect();
    let comm = match Link::fit(&comm_points) {
        Err(_) => None,
        Ok(line) => {
            let topo = comm_topo(&cluster, nodes, gpus_per_node);
            let mut base = fw.clone();
            base.calibrated_comm = None;
            let hw_latency = base.comm_time(&topo, 1.0);
            let overhead_s = (line.alpha - hw_latency).max(0.0);
            Some(CommFit {
                alpha_s: line.alpha - overhead_s,
                bw_bps: line.bw,
                overhead_s,
                samples: comm_points.len(),
            })
        }
    };

    Ok(NetCalibration {
        net: net.name,
        cluster: cluster.name,
        gpus: trace.gpus,
        batch,
        iterations: trace.iterations.len(),
        t_io_s,
        eff_conv,
        eff_fc,
        comm,
        layers,
    })
}

/// Calibrate a whole trace set (strict: the first bad trace is an
/// error — the CLI loops [`calibrate_one`] itself to skip-and-report).
pub fn calibrate(traces: &[Trace], fw: &Strategy) -> Result<CalibratedProfile, String> {
    let entries = traces
        .iter()
        .map(|t| calibrate_one(t, fw).map_err(|e| format!("{} on {}: {e}", t.net, t.cluster)))
        .collect::<Result<Vec<_>, String>>()?;
    if entries.is_empty() {
        return Err("no traces to calibrate".into());
    }
    Ok(CalibratedProfile {
        framework: fw.name.clone(),
        entries,
    })
}

impl CalibratedProfile {
    /// FNV-1a over the serialized profile — campaign cache keys for
    /// profile-driven cells embed this, so editing a profile file is a
    /// new cell, never a stale hit.
    pub fn content_hash(&self) -> u64 {
        fnv1a64(self.to_json().to_string().as_bytes())
    }

    /// Short content-addressed tag for cell keys and reports.
    pub fn tag(&self) -> String {
        format!("{}#{:016x}", self.framework, self.content_hash())
    }

    /// Serialize (schema v`PROFILE_SCHEMA_VERSION`).
    pub fn to_json(&self) -> Json {
        let entries: Vec<Json> = self
            .entries
            .iter()
            .map(|e| {
                let layers: Vec<Json> = e
                    .layers
                    .iter()
                    .map(|l| {
                        Json::obj(vec![
                            ("id", Json::num(l.id as f64)),
                            ("name", Json::str(l.name.clone())),
                            ("fwd_s", Json::num(l.fwd_s)),
                            ("bwd_s", Json::num(l.bwd_s)),
                            ("comm_s", Json::num(l.comm_s)),
                            ("size_bytes", Json::num(l.size_bytes as f64)),
                        ])
                    })
                    .collect();
                let opt = |v: Option<f64>| v.map(Json::num).unwrap_or(Json::Null);
                Json::obj(vec![
                    ("net", Json::str(e.net.clone())),
                    ("cluster", Json::str(e.cluster.clone())),
                    ("gpus", Json::num(e.gpus as f64)),
                    ("batch", Json::num(e.batch as f64)),
                    ("iterations", Json::num(e.iterations as f64)),
                    ("t_io_s", Json::num(e.t_io_s)),
                    ("eff_conv", opt(e.eff_conv)),
                    ("eff_fc", opt(e.eff_fc)),
                    (
                        "comm",
                        match e.comm {
                            None => Json::Null,
                            Some(c) => Json::obj(vec![
                                ("alpha_s", Json::num(c.alpha_s)),
                                ("bw_bps", Json::num(c.bw_bps)),
                                ("overhead_s", Json::num(c.overhead_s)),
                                ("samples", Json::num(c.samples as f64)),
                            ]),
                        },
                    ),
                    ("layers", Json::Arr(layers)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("schema_version", Json::num(PROFILE_SCHEMA_VERSION as f64)),
            ("bench", Json::str("calibration-profile")),
            ("framework", Json::str(self.framework.clone())),
            ("entries", Json::Arr(entries)),
        ])
    }

    /// Parse + validate a serialized profile.
    pub fn from_json(j: &Json) -> Result<CalibratedProfile, String> {
        let version = j
            .get("schema_version")
            .and_then(|v| v.as_f64())
            .ok_or("missing schema_version")?;
        if version != PROFILE_SCHEMA_VERSION as f64 {
            return Err(format!(
                "profile schema {version} != supported {PROFILE_SCHEMA_VERSION}"
            ));
        }
        if j.get("bench").and_then(|v| v.as_str()) != Some("calibration-profile") {
            return Err("bench tag must be \"calibration-profile\"".into());
        }
        let framework = j
            .get("framework")
            .and_then(|v| v.as_str())
            .ok_or("missing framework")?
            .to_string();
        let entries_json = j
            .get("entries")
            .and_then(|v| v.as_arr())
            .ok_or("missing entries array")?;
        if entries_json.is_empty() {
            return Err("entries array is empty".into());
        }
        let req_num = |cell: &Json, field: &str, at: &str| -> Result<f64, String> {
            let v = cell
                .get(field)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| format!("{at}: missing numeric '{field}'"))?;
            if !v.is_finite() || v < 0.0 {
                return Err(format!("{at}: '{field}' must be finite and ≥ 0"));
            }
            Ok(v)
        };
        let mut entries = Vec::with_capacity(entries_json.len());
        for (i, e) in entries_json.iter().enumerate() {
            let at = format!("entries[{i}]");
            let str_field = |field: &str| -> Result<String, String> {
                e.get(field)
                    .and_then(|v| v.as_str())
                    .map(|s| s.to_string())
                    .ok_or_else(|| format!("{at}: missing string '{field}'"))
            };
            let opt_eff = |field: &str| -> Result<Option<f64>, String> {
                match e.get(field) {
                    None | Some(Json::Null) => Ok(None),
                    Some(Json::Num(x)) if x.is_finite() && *x > 0.0 && *x <= 1.0 => Ok(Some(*x)),
                    _ => Err(format!("{at}: '{field}' must be null or in (0, 1]")),
                }
            };
            let comm = match e.get("comm") {
                None | Some(Json::Null) => None,
                Some(c) => {
                    let bw = req_num(c, "bw_bps", &at)?;
                    if bw <= 0.0 {
                        return Err(format!("{at}: comm bw_bps must be positive"));
                    }
                    Some(CommFit {
                        alpha_s: req_num(c, "alpha_s", &at)?,
                        bw_bps: bw,
                        overhead_s: req_num(c, "overhead_s", &at)?,
                        samples: req_num(c, "samples", &at)? as usize,
                    })
                }
            };
            let layers_json = e
                .get("layers")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| format!("{at}: missing layers array"))?;
            if layers_json.is_empty() {
                return Err(format!("{at}: layers array is empty"));
            }
            let mut layers = Vec::with_capacity(layers_json.len());
            for (li, l) in layers_json.iter().enumerate() {
                let lat = format!("{at}.layers[{li}]");
                layers.push(LayerCal {
                    id: req_num(l, "id", &lat)? as usize,
                    name: l
                        .get("name")
                        .and_then(|v| v.as_str())
                        .ok_or_else(|| format!("{lat}: missing name"))?
                        .to_string(),
                    fwd_s: req_num(l, "fwd_s", &lat)?,
                    bwd_s: req_num(l, "bwd_s", &lat)?,
                    comm_s: req_num(l, "comm_s", &lat)?,
                    size_bytes: req_num(l, "size_bytes", &lat)? as u64,
                });
            }
            let gpus = req_num(e, "gpus", &at)? as usize;
            if gpus == 0 {
                return Err(format!("{at}: gpus must be ≥ 1"));
            }
            entries.push(NetCalibration {
                net: str_field("net")?,
                cluster: str_field("cluster")?,
                gpus,
                batch: req_num(e, "batch", &at)? as usize,
                iterations: req_num(e, "iterations", &at)? as usize,
                t_io_s: req_num(e, "t_io_s", &at)?,
                eff_conv: opt_eff("eff_conv")?,
                eff_fc: opt_eff("eff_fc")?,
                comm,
                layers,
            });
        }
        Ok(CalibratedProfile { framework, entries })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::builder::JobSpec;
    use crate::frameworks::strategy as fw;
    use crate::trace::synth::synth_trace;
    use crate::util::json;

    fn trace_for(cluster: &ClusterSpec, net: NetSpec, gpus: (usize, usize), iters: usize) -> Trace {
        let job = JobSpec {
            batch_per_gpu: net.default_batch,
            net,
            nodes: gpus.0,
            gpus_per_node: gpus.1,
            iterations: 1,
        };
        synth_trace(cluster, &job, &fw::caffe_mpi(), iters, 11)
    }

    #[test]
    fn recovers_efficiency_within_tolerance() {
        for cluster in [presets::k80_cluster(), presets::v100_cluster()] {
            let truth = perf::efficiency_for(&cluster.gpu.name);
            for net in zoo::all() {
                let t = trace_for(&cluster, net.clone(), (4, 4), 30);
                let cal = calibrate_one(&t, &fw::caffe_mpi()).unwrap();
                let conv = cal.eff_conv.expect("conv layers are compute bound");
                assert!(
                    (conv / truth.conv - 1.0).abs() < 0.1,
                    "{} {}: conv eff {conv} vs {}",
                    cluster.name,
                    net.name,
                    truth.conv
                );
                if let Some(fc) = cal.eff_fc {
                    assert!(
                        (fc / truth.fc - 1.0).abs() < 0.1,
                        "{} {}: fc eff {fc} vs {}",
                        cluster.name,
                        net.name,
                        truth.fc
                    );
                }
            }
        }
    }

    #[test]
    fn comm_fit_reproduces_measured_allreduce_times() {
        let cluster = presets::k80_cluster();
        let t = trace_for(&cluster, zoo::alexnet(), (4, 4), 30);
        let cal = calibrate_one(&t, &fw::caffe_mpi()).unwrap();
        let c = cal.comm.expect("multi-GPU trace has comm");
        assert!(c.samples >= 5, "AlexNet has 8 learnable layers");
        assert!(c.bw_bps > 0.0 && c.alpha_s >= 0.0 && c.overhead_s >= 0.0);
        let model = cal.calibrated_comm().unwrap();
        // The fitted line must reproduce the big (bandwidth-bound)
        // messages closely; fc6 is 151 MB.
        let fc6 = cal.layers.iter().find(|l| l.name == "fc6").unwrap();
        let predicted = model.comm_time(fc6.size_bytes as f64);
        assert!(
            (predicted / fc6.comm_s - 1.0).abs() < 0.2,
            "fc6: fitted {predicted:.4}s vs measured {:.4}s",
            fc6.comm_s
        );
    }

    #[test]
    fn single_gpu_trace_has_no_comm_fit() {
        let cluster = presets::v100_cluster();
        let t = trace_for(&cluster, zoo::googlenet(), (1, 1), 4);
        let cal = calibrate_one(&t, &fw::caffe_mpi()).unwrap();
        assert!(cal.comm.is_none());
        assert!(cal.t_io_s > 0.0);
        assert_eq!(cal.gpus, 1);
        // Applying a comm-less calibration leaves the strategy stock.
        let applied = cal.apply_to(&fw::caffe_mpi());
        assert!(applied.calibrated_comm.is_none());
    }

    #[test]
    fn profile_json_roundtrip_is_exact() {
        let cluster = presets::k80_cluster();
        let traces = vec![
            trace_for(&cluster, zoo::alexnet(), (2, 4), 3),
            trace_for(&cluster, zoo::resnet50(), (1, 2), 3),
        ];
        let profile = calibrate(&traces, &fw::caffe_mpi()).unwrap();
        let text = profile.to_json().to_string();
        let back = CalibratedProfile::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, profile, "shortest-roundtrip floats preserve bits");
        assert_eq!(back.content_hash(), profile.content_hash());
        assert!(profile.tag().starts_with("caffe-mpi#"));
    }

    #[test]
    fn rejects_unknown_names_and_mismatched_rows() {
        let cluster = presets::k80_cluster();
        let mut t = trace_for(&cluster, zoo::alexnet(), (1, 2), 2);
        t.net = "vgg".into();
        assert!(calibrate_one(&t, &fw::caffe_mpi()).unwrap_err().contains("unknown net"));
        let mut t = trace_for(&cluster, zoo::alexnet(), (1, 2), 2);
        t.cluster = "mars".into();
        assert!(calibrate_one(&t, &fw::caffe_mpi())
            .unwrap_err()
            .contains("unknown cluster"));
        let mut t = trace_for(&cluster, zoo::alexnet(), (1, 2), 2);
        for it in &mut t.iterations {
            it.truncate(5);
        }
        assert!(calibrate_one(&t, &fw::caffe_mpi()).unwrap_err().contains("rows"));
        let mut t = trace_for(&cluster, zoo::alexnet(), (1, 2), 2);
        for it in &mut t.iterations {
            it[1].name = "convX".into();
        }
        assert!(calibrate_one(&t, &fw::caffe_mpi()).unwrap_err().contains("convX"));
    }

    /// The GPU-count check must not hide behind a successful comm fit:
    /// a comm-less (single-GPU-style) trace claiming an infeasible
    /// count is rejected at calibrate time, not at replay time.
    #[test]
    fn infeasible_gpu_counts_rejected_even_without_comm() {
        let cluster = presets::k80_cluster();
        let mut t = trace_for(&cluster, zoo::alexnet(), (1, 1), 2);
        assert!(t.iterations[0].iter().all(|r| r.comm_us == 0.0));
        t.gpus = 6;
        let err = calibrate_one(&t, &fw::caffe_mpi()).unwrap_err();
        assert!(err.contains("whole number"), "{err}");
    }

    #[test]
    fn split_ranks_covers_paper_topologies() {
        let k80 = presets::k80_cluster();
        assert_eq!(split_ranks(&k80, 1).unwrap(), (1, 1));
        assert_eq!(split_ranks(&k80, 4).unwrap(), (1, 4));
        assert_eq!(split_ranks(&k80, 8).unwrap(), (2, 4));
        assert_eq!(split_ranks(&k80, 16).unwrap(), (4, 4));
        assert!(split_ranks(&k80, 0).is_err());
        assert!(split_ranks(&k80, 6).is_err(), "partial nodes rejected");
        assert!(split_ranks(&k80, 64).is_err(), "more nodes than exist");
    }

    #[test]
    fn profile_validator_rejects_tampering() {
        let cluster = presets::v100_cluster();
        let profile =
            calibrate(&[trace_for(&cluster, zoo::googlenet(), (2, 4), 2)], &fw::mxnet()).unwrap();
        let good = profile.to_json().to_string();
        let parse = |s: &str| CalibratedProfile::from_json(&json::parse(s).unwrap());
        assert!(parse(&good).is_ok());
        assert!(parse(&good.replace("\"schema_version\":1", "\"schema_version\":9")).is_err());
        assert!(parse(&good.replace("calibration-profile", "something-else")).is_err());
        assert!(parse(&good.replace("\"gpus\":8", "\"gpus\":0")).is_err());
        assert!(parse("{\"schema_version\":1}").is_err());
    }
}
