//! Trace-directory ingestion: the entry point of the calibration loop.
//!
//! The paper closes by releasing its experimental traces "to support
//! simulation-based studies"; this module reads a directory in that
//! published layout back into [`Trace`]s. Files may carry the `#!`
//! metadata header our writer emits, or be headerless like the paper's
//! raw files — in the headerless case the job metadata is recovered from
//! the `<net>_<cluster>_g<G>_b<B>.trace` file-name convention
//! ([`dataset::parse_file_name`]). Unparseable or metadata-less files
//! are *skipped with a reason*, not fatal: a published directory often
//! carries READMEs, goldens and partial files next to the data.

use crate::trace::dataset;
use crate::trace::format::Trace;
use std::path::Path;

/// One ingested trace and where it came from.
#[derive(Clone, Debug)]
pub struct LoadedTrace {
    pub path: String,
    pub trace: Trace,
}

/// The result of scanning a trace directory.
#[derive(Clone, Debug, Default)]
pub struct TraceSet {
    /// Successfully parsed traces, in deterministic (sorted-path) order.
    pub traces: Vec<LoadedTrace>,
    /// `(path, reason)` for every `.trace` file that was not ingested.
    pub skipped: Vec<(String, String)>,
}

impl TraceSet {
    pub fn len(&self) -> usize {
        self.traces.len()
    }

    pub fn is_empty(&self) -> bool {
        self.traces.is_empty()
    }

    /// One-line ingest summary for the CLI.
    pub fn summary(&self) -> String {
        format!(
            "{} trace(s) ingested, {} file(s) skipped",
            self.traces.len(),
            self.skipped.len()
        )
    }
}

/// Fill metadata holes in a parsed trace from its file stem. Header
/// values win; the file name only supplies what the header left at its
/// defaults (the paper's raw files have no header at all).
fn apply_file_name_meta(trace: &mut Trace, stem: &str) {
    let Some((net, cluster, gpus, batch)) = dataset::parse_file_name(stem) else {
        return;
    };
    if trace.net.is_empty() {
        trace.net = net;
    }
    if trace.cluster.is_empty() {
        trace.cluster = cluster;
    }
    if trace.gpus == 0 {
        trace.gpus = gpus;
    }
    if trace.batch == 0 {
        trace.batch = batch;
    }
}

/// Minimum metadata calibration needs: a net name and a GPU count.
/// (A zero batch falls back to the net's paper-default downstream.)
fn meta_complete(trace: &Trace) -> Result<(), String> {
    if trace.net.is_empty() {
        return Err("no net name in header or file name".into());
    }
    if trace.cluster.is_empty() {
        return Err("no cluster name in header or file name".into());
    }
    if trace.gpus == 0 {
        return Err("no GPU count in header or file name".into());
    }
    Ok(())
}

/// Parse one trace file (text + its path for metadata recovery).
pub fn parse_trace_file(path: &Path, text: &str) -> Result<Trace, String> {
    finish_trace(Trace::parse(text)?, path)
}

/// Streaming twin of [`parse_trace_file`]: parse straight off a buffered
/// reader (one reused line buffer, no whole-file `String`), then apply
/// the same file-name metadata recovery and completeness checks.
pub fn parse_trace_reader<R: std::io::BufRead>(path: &Path, reader: R) -> Result<Trace, String> {
    finish_trace(Trace::parse_reader(reader)?, path)
}

fn finish_trace(mut trace: Trace, path: &Path) -> Result<Trace, String> {
    if let Some(stem) = path.file_stem().and_then(|s| s.to_str()) {
        apply_file_name_meta(&mut trace, stem);
    }
    meta_complete(&trace)?;
    Ok(trace)
}

/// Scan `dir` for `*.trace` files and parse them. Errors only when the
/// directory itself is unreadable or yields zero usable traces; bad
/// individual files land in [`TraceSet::skipped`].
pub fn load_dir(dir: &Path) -> Result<TraceSet, String> {
    let entries =
        std::fs::read_dir(dir).map_err(|e| format!("cannot read {}: {e}", dir.display()))?;
    let mut paths: Vec<std::path::PathBuf> = entries
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().and_then(|x| x.to_str()) == Some("trace"))
        .collect();
    paths.sort();
    let mut set = TraceSet::default();
    for path in paths {
        let shown = path.display().to_string();
        // Stream each file through a buffered reader: directories of
        // 100-iteration traces ingest without ever holding a whole file
        // in memory (the PR 4 `read_to_string` note, closed).
        let file = match std::fs::File::open(&path) {
            Ok(f) => f,
            Err(e) => {
                set.skipped.push((shown, format!("unreadable: {e}")));
                continue;
            }
        };
        match parse_trace_reader(&path, std::io::BufReader::new(file)) {
            Ok(trace) => set.traces.push(LoadedTrace { path: shown, trace }),
            Err(why) => set.skipped.push((shown, why)),
        }
    }
    if set.traces.is_empty() {
        return Err(format!(
            "no usable .trace files in {} ({} skipped)",
            dir.display(),
            set.skipped.len()
        ));
    }
    Ok(set)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::dataset::write_dataset;
    use std::fs;

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("dagsgd-ingest-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn loads_the_published_dataset_layout() {
        let dir = tmp_dir("dataset");
        write_dataset(&dir, 2, 9).unwrap();
        let set = load_dir(&dir).unwrap();
        // 6 synthetic files + the Table VI golden (whose header carries
        // full metadata even though its stem doesn't parse).
        assert_eq!(set.len(), 7, "{:?}", set.skipped);
        assert!(set.skipped.is_empty(), "{:?}", set.skipped);
        for t in &set.traces {
            assert!(!t.trace.net.is_empty());
            assert!(t.trace.gpus > 0, "{}", t.path);
        }
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn headerless_file_recovers_metadata_from_its_name() {
        let dir = tmp_dir("headerless");
        let body = "0 data 1.2e6 0 0 0\n1 conv1 3.27e6 288202 123.424 139776\n";
        fs::write(dir.join("alexnet_k80-pcie-10gbe_g16_b1024.trace"), body).unwrap();
        let set = load_dir(&dir).unwrap();
        assert_eq!(set.len(), 1);
        let t = &set.traces[0].trace;
        assert_eq!(t.net, "alexnet");
        assert_eq!(t.cluster, "k80-pcie-10gbe");
        assert_eq!(t.gpus, 16);
        assert_eq!(t.batch, 1024);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn bad_files_are_skipped_not_fatal() {
        let dir = tmp_dir("skipped");
        let body = "0 data 1.2e6 0 0 0\n";
        fs::write(dir.join("alexnet_k80_g4_b64.trace"), body).unwrap();
        // Malformed rows.
        fs::write(dir.join("googlenet_k80_g4_b64.trace"), "not a trace\n").unwrap();
        // Headerless AND un-inferable name.
        fs::write(dir.join("mystery.trace"), body).unwrap();
        // Ignored entirely: wrong extension.
        fs::write(dir.join("README.md"), "docs\n").unwrap();
        let set = load_dir(&dir).unwrap();
        assert_eq!(set.len(), 1);
        assert_eq!(set.skipped.len(), 2, "{:?}", set.skipped);
        assert!(set.summary().contains("1 trace(s)"));
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn empty_or_missing_dir_is_an_error() {
        let dir = tmp_dir("empty");
        assert!(load_dir(&dir).unwrap_err().contains("no usable"));
        assert!(load_dir(&dir.join("nope")).unwrap_err().contains("cannot read"));
        fs::remove_dir_all(&dir).unwrap();
    }
}
