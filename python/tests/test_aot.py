"""AOT path correctness: lowering produces loadable HLO text and a
metadata bundle consistent with the model, using a tiny config so the
test stays fast."""

import json
import os

import jax
import numpy as np

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")

CFG = model.Config(vocab=32, d_model=16, n_heads=2, n_layers=1, seq=8, batch=2)


def test_hlo_text_looks_like_hlo():
    text = aot.lower_train_step(CFG)
    assert "HloModule" in text
    assert "ENTRY" in text
    # One output per gradient + loss, one input per param + 2 batch args.
    nparams = len(model.param_spec(CFG))
    assert text.count("parameter(") >= nparams + 2


def test_update_step_lowering():
    text = aot.lower_update_step(CFG)
    assert "HloModule" in text
    # SGD is a subtract/multiply graph; no dot ops needed.
    assert "subtract" in text or "fusion" in text


def test_build_writes_consistent_bundle(tmp_path):
    meta = aot.build(CFG, str(tmp_path), seed=3)
    # Files exist.
    for f in ["train_step.hlo.txt", "update_step.hlo.txt", "params.bin", "meta.json"]:
        assert os.path.exists(tmp_path / f), f
    # meta.json round-trips and matches the returned dict.
    on_disk = json.loads((tmp_path / "meta.json").read_text())
    assert on_disk == meta
    # Param table covers the blob exactly.
    blob = (tmp_path / "params.bin").read_bytes()
    assert len(blob) == meta["total_params"] * 4
    offsets = [p["offset"] for p in meta["params"]]
    assert offsets == sorted(offsets)
    assert meta["total_params"] == model.param_count(CFG)
    # The blob holds the same values init_params produces.
    params = model.init_params(CFG, seed=3)
    flat = np.frombuffer(blob, dtype="<f4")
    for info, p in zip(meta["params"], params):
        seg = flat[info["offset"] : info["offset"] + info["numel"]]
        np.testing.assert_array_equal(seg, np.asarray(p).reshape(-1))


def test_param_spec_matches_rust_expectation():
    # The Rust loader asserts 2 + 12*n_layers + 3 tensors.
    assert len(model.param_spec(CFG)) == 2 + 12 * CFG.n_layers + 3
