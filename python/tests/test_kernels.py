"""L1 correctness: every Pallas kernel against its pure-jnp oracle,
swept over shapes with hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import layernorm as ln
from compile.kernels import matmul as mm
from compile.kernels import ref
from compile.kernels import sgd
from compile.kernels import softmax as sm

jax.config.update("jax_platform_name", "cpu")

DIMS = st.integers(min_value=1, max_value=160)
SMALL_DIMS = st.integers(min_value=1, max_value=96)


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------- matmul


@settings(max_examples=25, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS)
def test_matmul_matches_ref(m, k, n):
    x, y = rand(0, m, k), rand(1, k, n)
    np.testing.assert_allclose(mm.matmul(x, y), ref.matmul(x, y), rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(m=SMALL_DIMS, k=SMALL_DIMS, n=SMALL_DIMS)
def test_matmul_bias_matches_ref(m, k, n):
    x, y, b = rand(0, m, k), rand(1, k, n), rand(2, n)
    np.testing.assert_allclose(
        mm.matmul(x, y, bias=b), ref.matmul(x, y, bias=b), rtol=2e-4, atol=2e-4
    )


@settings(max_examples=15, deadline=None)
@given(m=SMALL_DIMS, k=SMALL_DIMS, n=SMALL_DIMS)
def test_matmul_gelu_matches_ref(m, k, n):
    x, y, b = rand(0, m, k), rand(1, k, n), rand(2, n)
    np.testing.assert_allclose(
        mm.matmul(x, y, bias=b, activation="gelu"),
        ref.matmul(x, y, bias=b, activation="gelu"),
        rtol=2e-4,
        atol=2e-4,
    )


@pytest.mark.parametrize("bm,bn,bk", [(32, 32, 32), (64, 128, 32), (128, 128, 128)])
def test_matmul_block_shapes_equivalent(bm, bn, bk):
    x, y = rand(0, 200, 144), rand(1, 144, 72)
    expect = ref.matmul(x, y)
    got = mm.matmul(x, y, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(got, expect, rtol=2e-4, atol=2e-4)


def test_matmul_rejects_bad_activation():
    x, y = rand(0, 8, 8), rand(1, 8, 8)
    with pytest.raises(ValueError):
        mm.matmul(x, y, activation="relu6")


def test_vmem_and_mxu_estimates():
    assert mm.vmem_bytes(128, 128, 128) == 4 * (3 * 128 * 128 + 128)
    assert mm.mxu_utilization(128, 128, 128) == 1.0
    assert mm.mxu_utilization(64, 128, 128) == 0.5


# -------------------------------------------------------------- layernorm


@settings(max_examples=20, deadline=None)
@given(r=DIMS, d=st.integers(min_value=2, max_value=256))
def test_layernorm_matches_ref(r, d):
    x, g, b = rand(0, r, d), rand(1, d), rand(2, d)
    np.testing.assert_allclose(
        ln.layernorm(x, g, b), ref.layernorm(x, g, b), rtol=1e-4, atol=1e-4
    )


def test_layernorm_normalizes():
    x = rand(3, 64, 128) * 10 + 5
    out = ln.layernorm(x, jnp.ones(128), jnp.zeros(128))
    np.testing.assert_allclose(np.mean(out, axis=-1), 0.0, atol=1e-4)
    np.testing.assert_allclose(np.std(out, axis=-1), 1.0, atol=1e-2)


# ---------------------------------------------------------------- softmax


@settings(max_examples=20, deadline=None)
@given(r=DIMS, n=st.integers(min_value=1, max_value=128))
def test_softmax_matches_ref(r, n):
    x = rand(0, r, n) * 5
    np.testing.assert_allclose(
        sm.softmax_rows(x), ref.softmax_rows(x), rtol=1e-5, atol=1e-6
    )


@settings(max_examples=10, deadline=None)
@given(b=st.integers(min_value=1, max_value=6), s=st.integers(min_value=1, max_value=48))
def test_causal_softmax_masks_future(b, s):
    x = rand(1, b * s, s) * 3
    p = np.asarray(sm.softmax_rows(x, causal=True))
    for r in range(b * s):
        pos = r % s
        assert np.all(p[r, pos + 1 :] == 0.0), f"row {r} leaks future"
        np.testing.assert_allclose(p[r, : pos + 1].sum(), 1.0, rtol=1e-5)


def test_softmax_rows_sum_to_one():
    x = rand(2, 100, 50) * 10
    p = np.asarray(sm.softmax_rows(x))
    np.testing.assert_allclose(p.sum(axis=-1), 1.0, rtol=1e-5)


# -------------------------------------------------------------------- sgd


@settings(max_examples=20, deadline=None)
@given(n=st.integers(min_value=1, max_value=100_000), lr=st.floats(0.0, 1.0))
def test_sgd_matches_ref(n, lr):
    p, g = rand(0, n), rand(1, n)
    np.testing.assert_allclose(
        sgd.sgd_update(p, g, lr), ref.sgd_update(p, g, lr), rtol=1e-6, atol=1e-6
    )


def test_sgd_preserves_shape():
    p, g = rand(0, 12, 34), rand(1, 12, 34)
    out = sgd.sgd_update(p, g, 0.1)
    assert out.shape == (12, 34)
    np.testing.assert_allclose(out, np.asarray(p) - 0.1 * np.asarray(g), rtol=1e-6)


def test_sgd_zero_lr_is_identity():
    p, g = rand(0, 1000), rand(1, 1000)
    np.testing.assert_allclose(sgd.sgd_update(p, g, 0.0), p)
