"""L2 correctness: the transformer model and its AOT entry points."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model

jax.config.update("jax_platform_name", "cpu")

CFG = model.Config(vocab=64, d_model=32, n_heads=2, n_layers=2, seq=16, batch=2)


def test_param_spec_shapes_consistent():
    spec = model.param_spec(CFG)
    params = model.init_params(CFG)
    assert len(spec) == len(params)
    for (name, shape), p in zip(spec, params):
        assert p.shape == shape, name
    # 2 embeddings + 12 per block × 2 blocks + 3 tail.
    assert len(spec) == 2 + 12 * 2 + 3


def test_param_count_matches_arrays():
    params = model.init_params(CFG)
    total = sum(int(np.prod(p.shape)) for p in params)
    assert model.param_count(CFG) == total


def test_forward_shape_and_finite():
    params = model.init_params(CFG)
    tokens, _ = model.example_batch(CFG)
    logits = model.forward(params, tokens, CFG)
    assert logits.shape == (CFG.batch * CFG.seq, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_pallas_model_matches_reference_model():
    """The headline L1/L2 equivalence: same params, same tokens — the
    Pallas-kernel model and the pure-jnp model agree on loss AND grads."""
    params = model.init_params(CFG, seed=3)
    tokens, targets = model.example_batch(CFG, seed=4)

    loss_p, grads_p = jax.value_and_grad(
        lambda ps: model.loss_fn(ps, tokens, targets, CFG)
    )(params)
    loss_r, grads_r = jax.value_and_grad(
        lambda ps: model.loss_fn_ref(ps, tokens, targets, CFG)
    )(params)

    np.testing.assert_allclose(loss_p, loss_r, rtol=1e-4)
    for gp, gr, (name, _) in zip(grads_p, grads_r, model.param_spec(CFG)):
        np.testing.assert_allclose(gp, gr, rtol=3e-3, atol=3e-4, err_msg=name)


def test_initial_loss_near_uniform():
    """Untrained model ≈ uniform predictions: loss ≈ ln(vocab)."""
    params = model.init_params(CFG)
    tokens, targets = model.example_batch(CFG)
    loss = float(model.loss_fn(params, tokens, targets, CFG))
    assert abs(loss - np.log(CFG.vocab)) < 1.0, loss


def test_train_step_output_arity():
    step = model.make_train_step(CFG)
    params = model.init_params(CFG)
    tokens, targets = model.example_batch(CFG)
    out = step(*params, tokens, targets)
    assert len(out) == 1 + len(params)
    assert out[0].shape == ()
    for g, p in zip(out[1:], params):
        assert g.shape == p.shape


def test_update_step_applies_sgd():
    upd = model.make_update_step(CFG)
    params = model.init_params(CFG)
    grads = [jnp.ones_like(p) for p in params]
    new = upd(*params, *grads)
    for n, p in zip(new, params):
        np.testing.assert_allclose(np.asarray(n), np.asarray(p) - CFG.lr, rtol=1e-5)


def test_few_steps_reduce_loss_on_fixed_batch():
    """Single-worker sanity: SGD on one repeated batch must descend."""
    cfg = CFG
    step = jax.jit(model.make_train_step(cfg))
    params = model.init_params(cfg, seed=0)
    tokens, targets = model.example_batch(cfg, seed=1)
    first = None
    last = None
    for _ in range(8):
        out = step(*params, tokens, targets)
        loss, grads = out[0], out[1:]
        if first is None:
            first = float(loss)
        last = float(loss)
        params = [p - cfg.lr * g for p, g in zip(params, grads)]
    assert last < first * 0.9, (first, last)


def test_causal_masking_in_model():
    """Changing future tokens must not change earlier logits."""
    params = model.init_params(CFG, seed=5)
    tokens, _ = model.example_batch(CFG, seed=6)
    logits_a = model.forward(params, tokens, CFG).reshape(
        CFG.batch, CFG.seq, CFG.vocab
    )
    tampered = tokens.at[:, -1].set((tokens[:, -1] + 7) % CFG.vocab)
    logits_b = model.forward(params, tampered, CFG).reshape(
        CFG.batch, CFG.seq, CFG.vocab
    )
    np.testing.assert_allclose(
        logits_a[:, : CFG.seq - 1], logits_b[:, : CFG.seq - 1], rtol=1e-4, atol=1e-5
    )
