"""Gradient correctness of the differentiable Pallas ops: each custom VJP
against jax.grad of the pure-jnp reference."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ops

jax.config.update("jax_platform_name", "cpu")

D = st.integers(min_value=2, max_value=64)


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


def grads_close(f_pallas, f_ref, args, rtol=3e-4, atol=3e-4):
    g_pallas = jax.grad(lambda *a: jnp.sum(f_pallas(*a) ** 2), argnums=range(len(args)))(*args)
    g_ref = jax.grad(lambda *a: jnp.sum(f_ref(*a) ** 2), argnums=range(len(args)))(*args)
    for gp, gr in zip(g_pallas, g_ref):
        np.testing.assert_allclose(gp, gr, rtol=rtol, atol=atol)


@settings(max_examples=10, deadline=None)
@given(m=D, k=D, n=D)
def test_matmul_grads(m, k, n):
    args = (rand(0, m, k), rand(1, k, n), rand(2, n))
    grads_close(ops.matmul, ops.matmul_ref, args)


@settings(max_examples=10, deadline=None)
@given(m=D, k=D, n=D)
def test_matmul_gelu_grads(m, k, n):
    args = (rand(0, m, k), rand(1, k, n), rand(2, n))
    grads_close(ops.matmul_gelu, ops.matmul_gelu_ref, args)


@settings(max_examples=10, deadline=None)
@given(r=D, d=D)
def test_layernorm_grads(r, d):
    args = (rand(0, r, d), rand(1, d), rand(2, d))
    grads_close(ops.layernorm, ops.layernorm_ref, args, rtol=1e-3, atol=1e-3)


@settings(max_examples=8, deadline=None)
@given(b=st.integers(min_value=1, max_value=4), s=st.integers(min_value=2, max_value=24))
def test_causal_softmax_grads(b, s):
    args = (rand(0, b * s, s),)
    grads_close(ops.causal_softmax, ops.causal_softmax_ref, args, rtol=1e-4, atol=1e-5)


def test_matmul_grad_finite_differences():
    """Independent check that the custom VJP isn't just matching a wrong
    reference: central finite differences on a tiny case."""
    x, y, b = rand(0, 3, 4), rand(1, 4, 2), rand(2, 2)

    def f(x_):
        return float(jnp.sum(ops.matmul(x_, y, b) ** 2))

    g = np.asarray(jax.grad(lambda x_: jnp.sum(ops.matmul(x_, y, b) ** 2))(x))
    eps = 1e-3
    for i in range(3):
        for j in range(4):
            xp = np.asarray(x).copy()
            xm = np.asarray(x).copy()
            xp[i, j] += eps
            xm[i, j] -= eps
            fd = (f(jnp.asarray(xp)) - f(jnp.asarray(xm))) / (2 * eps)
            np.testing.assert_allclose(g[i, j], fd, rtol=2e-2, atol=2e-3)
