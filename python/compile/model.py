"""Layer-2: the JAX model — a decoder-only transformer LM trained with
S-SGD by the Rust coordinator.

Forward/backward are built on the differentiable Pallas ops in
``kernels.ops`` (tiled matmul + fused epilogues, fused LayerNorm, causal
softmax). ``train_step`` takes the flat parameter list plus a token batch
and returns ``(loss, *gradients)``; ``update_step`` applies SGD via the
Pallas update kernel. Both are AOT-lowered to HLO text by ``aot.py`` and
executed from Rust — Python never runs at training time.

A pure-jnp twin (``*_ref``) of the whole model exists for the kernel-vs-
reference equivalence tests.
"""

import dataclasses
from typing import List

import jax
import jax.numpy as jnp

from .kernels import ops
from .kernels import sgd as sgd_kernel


@dataclasses.dataclass(frozen=True)
class Config:
    """Transformer hyper-parameters (sizes chosen MXU/VMEM-friendly —
    multiples of 128 where it matters)."""

    vocab: int = 512
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 2
    seq: int = 64
    batch: int = 8
    lr: float = 0.05

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model


def param_spec(cfg: Config) -> List[tuple]:
    """Ordered (name, shape) of every parameter tensor. This order *is*
    the ABI between the artifacts and the Rust runtime (meta.json)."""
    spec = [
        ("tok_emb", (cfg.vocab, cfg.d_model)),
        ("pos_emb", (cfg.seq, cfg.d_model)),
    ]
    for i in range(cfg.n_layers):
        p = f"block{i}."
        spec += [
            (p + "ln1.g", (cfg.d_model,)),
            (p + "ln1.b", (cfg.d_model,)),
            (p + "attn.wqkv", (cfg.d_model, 3 * cfg.d_model)),
            (p + "attn.bqkv", (3 * cfg.d_model,)),
            (p + "attn.wo", (cfg.d_model, cfg.d_model)),
            (p + "attn.bo", (cfg.d_model,)),
            (p + "ln2.g", (cfg.d_model,)),
            (p + "ln2.b", (cfg.d_model,)),
            (p + "mlp.w1", (cfg.d_model, cfg.d_ff)),
            (p + "mlp.b1", (cfg.d_ff,)),
            (p + "mlp.w2", (cfg.d_ff, cfg.d_model)),
            (p + "mlp.b2", (cfg.d_model,)),
        ]
    spec += [
        ("lnf.g", (cfg.d_model,)),
        ("lnf.b", (cfg.d_model,)),
        ("head", (cfg.d_model, cfg.vocab)),
    ]
    return spec


def param_count(cfg: Config) -> int:
    total = 0
    for _, shape in param_spec(cfg):
        n = 1
        for d in shape:
            n *= d
        total += n
    return total


def init_params(cfg: Config, seed: int = 0) -> List[jnp.ndarray]:
    """Scaled-normal init for matrices, ones/zeros for norms and biases."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith((".g",)):
            params.append(jnp.ones(shape, jnp.float32))
        elif name.endswith((".b", ".bqkv", ".bo", ".b1", ".b2")):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            scale = 0.02 if "emb" in name else (1.0 / shape[0]) ** 0.5
            params.append(scale * jax.random.normal(sub, shape, jnp.float32))
    return params


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------


def _forward(params, tokens, cfg: Config, k):
    """Logits (B·S, V). `k` selects the kernel set: pallas ops or the
    pure-jnp reference twins."""
    matmul, matmul_gelu, layernorm, csoftmax = k
    it = iter(params)

    def take():
        return next(it)

    tok_emb, pos_emb = take(), take()
    b, s = tokens.shape
    d = cfg.d_model
    x = tok_emb[tokens] + pos_emb[None, :, :]  # (B, S, D)
    x = x.reshape(b * s, d)

    for _ in range(cfg.n_layers):
        ln1_g, ln1_b = take(), take()
        wqkv, bqkv = take(), take()
        wo, bo = take(), take()
        ln2_g, ln2_b = take(), take()
        w1, b1, w2, b2 = take(), take(), take(), take()

        # --- attention ---
        h = layernorm(x, ln1_g, ln1_b)
        qkv = matmul(h, wqkv, bqkv)  # (B·S, 3D)
        q, kk, v = jnp.split(qkv, 3, axis=1)

        def heads(t):
            return (
                t.reshape(b, s, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
            )  # (B, H, S, dh)

        q, kk, v = heads(q), heads(kk), heads(v)
        scores = jnp.einsum("bhqd,bhkd->bhqk", q, kk) / jnp.sqrt(
            jnp.float32(cfg.d_head)
        )
        probs = csoftmax(scores.reshape(b * cfg.n_heads * s, s)).reshape(
            b, cfg.n_heads, s, s
        )
        ctx = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
        ctx = ctx.transpose(0, 2, 1, 3).reshape(b * s, d)
        x = x + matmul(ctx, wo, bo)

        # --- MLP ---
        h = layernorm(x, ln2_g, ln2_b)
        h = matmul_gelu(h, w1, b1)
        x = x + matmul(h, w2, b2)

    lnf_g, lnf_b = take(), take()
    head = take()
    x = layernorm(x, lnf_g, lnf_b)
    logits = matmul(x, head, jnp.zeros((cfg.vocab,), jnp.float32))
    return logits


_PALLAS_KERNELS = (ops.matmul, ops.matmul_gelu, ops.layernorm, ops.causal_softmax)
_REF_KERNELS = (
    ops.matmul_ref,
    ops.matmul_gelu_ref,
    ops.layernorm_ref,
    ops.causal_softmax_ref,
)


def forward(params, tokens, cfg: Config):
    return _forward(params, tokens, cfg, _PALLAS_KERNELS)


def forward_ref(params, tokens, cfg: Config):
    return _forward(params, tokens, cfg, _REF_KERNELS)


def _loss_from_logits(logits, targets, vocab):
    tgt = targets.reshape(-1)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[:, None], axis=1)
    return jnp.mean(nll)


def loss_fn(params, tokens, targets, cfg: Config):
    """Mean next-token cross-entropy."""
    return _loss_from_logits(forward(params, tokens, cfg), targets, cfg.vocab)


def loss_fn_ref(params, tokens, targets, cfg: Config):
    return _loss_from_logits(forward_ref(params, tokens, cfg), targets, cfg.vocab)


# --------------------------------------------------------------------------
# the two AOT entry points
# --------------------------------------------------------------------------


def make_train_step(cfg: Config):
    """`(params..., tokens, targets) → (loss, grad_0, ..., grad_{P-1})`."""
    nparams = len(param_spec(cfg))

    def train_step(*args):
        params = list(args[:nparams])
        tokens, targets = args[nparams], args[nparams + 1]
        loss, grads = jax.value_and_grad(
            lambda ps: loss_fn(ps, tokens, targets, cfg)
        )(params)
        return (loss, *grads)

    return train_step


def make_update_step(cfg: Config):
    """`(params..., grads...) → (new_params...)` via the Pallas SGD kernel
    (learning rate is baked into the artifact, like a compiled optimizer)."""
    nparams = len(param_spec(cfg))

    def update_step(*args):
        params = args[:nparams]
        grads = args[nparams:]
        return tuple(
            sgd_kernel.sgd_update(p, g, cfg.lr) for p, g in zip(params, grads)
        )

    return update_step


def example_batch(cfg: Config, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    tokens = jax.random.randint(k1, (cfg.batch, cfg.seq), 0, cfg.vocab)
    targets = jax.random.randint(k2, (cfg.batch, cfg.seq), 0, cfg.vocab)
    return tokens, targets
