"""Layer-1 Pallas kernel: tiled matmul with fused bias + GELU epilogue.

This is the compute hot-spot of the L2 transformer (QKV/out projections,
MLP, LM head). Hardware adaptation of the paper's cuDNN GEMMs (DESIGN.md
§Hardware-Adaptation):

* the CUDA threadblock tiling becomes a Pallas ``grid`` over (M/bm, N/bn,
  K/bk) with ``BlockSpec`` index maps describing the HBM→VMEM schedule;
* the tensor-core WMMA tile becomes an MXU-shaped ``bm×bk @ bk×bn`` block
  matmul (default 128×128×128 — one MXU-aligned tile, fp32 accumulate);
* the bias/activation epilogue is fused into the last K-step while the
  accumulator tile is still VMEM-resident (cuDNN's fused epilogue).

VMEM footprint per grid step = (bm·bk + bk·bn + bm·bn + bn) · 4 B
≈ 192 KiB at the default tile — far under the ~16 MiB VMEM budget, leaving
room for double-buffering (see DESIGN.md §Perf).

Lowered with ``interpret=True``: the CPU PJRT client cannot execute Mosaic
custom-calls; on a real TPU the same code compiles natively.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-aligned default tile.
DEFAULT_BLOCK = 128


def _matmul_kernel(x_ref, y_ref, b_ref, o_ref, *, nsteps_k, activation):
    """Grid point (i, j, k): accumulate X[i,k] @ Y[k,j] into O[i,j]."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nsteps_k - 1)
    def _epilogue():
        acc = o_ref[...]
        if b_ref is not None:
            acc = acc + b_ref[...]
        if activation == "gelu":
            c = jnp.sqrt(2.0 / jnp.pi).astype(acc.dtype)
            acc = 0.5 * acc * (1.0 + jnp.tanh(c * (acc + 0.044715 * acc**3)))
        o_ref[...] = acc


def _pad_to(x, axis, multiple):
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit, static_argnames=("activation", "bm", "bn", "bk", "interpret")
)
def matmul(
    x,
    y,
    bias=None,
    activation=None,
    bm=DEFAULT_BLOCK,
    bn=DEFAULT_BLOCK,
    bk=DEFAULT_BLOCK,
    interpret=True,
):
    """``x @ y (+ bias) (∘ gelu)`` via the Pallas kernel.

    ``x``: (M, K), ``y``: (K, N), ``bias``: (N,) or None. Arbitrary M/N/K —
    inputs are zero-padded up to tile multiples and the result sliced back.
    """
    assert x.ndim == 2 and y.ndim == 2 and x.shape[1] == y.shape[0]
    if activation not in (None, "gelu"):
        raise ValueError(f"unsupported activation {activation}")
    m, kdim = x.shape
    n = y.shape[1]
    # Shrink tiles for small problems, then pad to multiples.
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, kdim)
    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    yp = _pad_to(_pad_to(y, 0, bk), 1, bn)
    mp, kp = xp.shape
    np_ = yp.shape[1]
    grid = (mp // bm, np_ // bn, kp // bk)

    args = [xp, yp]
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
    ]
    if bias is not None:
        assert bias.shape == (n,)
        args.append(_pad_to(bias, 0, bn))
        in_specs.append(pl.BlockSpec((bn,), lambda i, j, kk: (j,)))
        kernel = functools.partial(
            _matmul_kernel, nsteps_k=grid[2], activation=activation
        )
    else:
        kernel = functools.partial(
            lambda xr, yr, orf, **kw: _matmul_kernel(xr, yr, None, orf, **kw),
            nsteps_k=grid[2],
            activation=activation,
        )

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(*args)
    return out[:m, :n]


def vmem_bytes(bm=DEFAULT_BLOCK, bn=DEFAULT_BLOCK, bk=DEFAULT_BLOCK, with_bias=True):
    """Estimated VMEM bytes held per grid step (perf-model input)."""
    tiles = bm * bk + bk * bn + bm * bn + (bn if with_bias else 0)
    return 4 * tiles


def mxu_utilization(bm=DEFAULT_BLOCK, bn=DEFAULT_BLOCK, bk=DEFAULT_BLOCK):
    """Fraction of a 128×128 MXU an individual block matmul can feed
    (1.0 when every tile dimension is a multiple of 128)."""
    def frac(d):
        return min(d, 128) / 128.0

    return frac(bm) * frac(bn) * frac(bk)
