"""L1 performance report: VMEM footprint and MXU-utilization estimates for
every GEMM the transformer config runs through the Pallas matmul kernel.

`interpret=True` gives CPU-numpy timings, which say nothing about TPU
performance — so the §Perf deliverable for L1 is *structural*: tile sizes
vs the ~16 MiB VMEM budget and MXU alignment of every operand. Run:

    cd python && python -m compile.kernels.report [--d-model 128 ...]
"""

import argparse

from . import matmul as mm
from .. import model


def gemm_shapes(cfg: model.Config):
    """Every (name, M, K, N) GEMM in one fwd+bwd step (per worker)."""
    rows = cfg.batch * cfg.seq
    d, ff, v = cfg.d_model, cfg.d_ff, cfg.vocab
    shapes = []
    for i in range(cfg.n_layers):
        shapes += [
            (f"block{i}.qkv", rows, d, 3 * d),
            (f"block{i}.attn_out", rows, d, d),
            (f"block{i}.mlp_in(gelu)", rows, d, ff),
            (f"block{i}.mlp_out", rows, ff, d),
        ]
    shapes.append(("head", rows, d, v))
    # Backward adds dgrad (M,N)x(N,K) and wgrad (K,M)x(M,N) per GEMM.
    bwd = []
    for name, m, k, n in shapes:
        bwd.append((name + ".dgrad", m, n, k))
        bwd.append((name + ".wgrad", k, m, n))
    return shapes + bwd


def report(cfg: model.Config, bm=128, bn=128, bk=128):
    lines = []
    total_flops = 0.0
    worst_util = 1.0
    for name, m, k, n in gemm_shapes(cfg):
        eb_m, eb_k, eb_n = min(bm, m), min(bk, k), min(bn, n)
        vmem = mm.vmem_bytes(eb_m, eb_n, eb_k)
        util = mm.mxu_utilization(eb_m, eb_n, eb_k)
        worst_util = min(worst_util, util)
        flops = 2.0 * m * k * n
        total_flops += flops
        lines.append(
            f"{name:24} {m:>6}x{k:<6}x{n:<6} tile {eb_m}x{eb_k}x{eb_n} "
            f"vmem {vmem / 1024:8.1f}KiB  mxu {util * 100:5.1f}%  "
            f"{flops / 1e6:9.1f} MFLOP"
        )
    header = (
        f"L1 GEMM report — d_model={cfg.d_model} layers={cfg.n_layers} "
        f"batch={cfg.batch} seq={cfg.seq} (tiles ≤ {bm}x{bk}x{bn})"
    )
    budget = 16 * 1024 * 1024
    max_vmem = max(
        mm.vmem_bytes(min(bm, m), min(bn, n), min(bk, k))
        for _, m, k, n in gemm_shapes(cfg)
    )
    footer = (
        f"total {total_flops / 1e9:.2f} GFLOP/step | max tile VMEM "
        f"{max_vmem / 1024:.1f}KiB of {budget // 1024}KiB budget "
        f"({budget / max_vmem:.0f}x double-buffer headroom) | worst MXU "
        f"utilization {worst_util * 100:.1f}%"
    )
    return header, lines, footer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--n-heads", type=int, default=4)
    ap.add_argument("--n-layers", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()
    cfg = model.Config(
        vocab=args.vocab,
        d_model=args.d_model,
        n_heads=args.n_heads,
        n_layers=args.n_layers,
        seq=args.seq,
        batch=args.batch,
    )
    header, lines, footer = report(cfg)
    print(header)
    for l in lines:
        print(" ", l)
    print(footer)


if __name__ == "__main__":
    main()
