"""Layer-1 Pallas kernel: masked row softmax (attention probabilities).

The row dimension is tiled; the full softmax axis lives in one VMEM block
(attention rows are seq-length sized — ≤ a few K elements — so a
register/VMEM single-pass max-subtract-exp-normalize is the natural TPU
shape for CUDA's warp-reduction softmax).

The causal mask is computed inside the kernel from absolute row/column
indices, so no mask tensor ever travels through HBM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_ROW_BLOCK = 128


def _softmax_kernel(x_ref, o_ref, *, rows, causal):
    x = x_ref[...]
    if causal:
        # Absolute row index within the (padded) matrix; the softmax axis
        # is the key position. Rows attend to columns ≤ their own seq pos.
        i = pl.program_id(0)
        n = x.shape[-1]
        row = i * rows + jax.lax.broadcasted_iota(jnp.int32, x.shape, 0)
        col = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
        # Row r of the flattened (batch·seq) matrix has seq position r % n.
        keep = col <= (row % n)
        x = jnp.where(keep, x, jnp.finfo(x.dtype).min)
    x = x - jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x)
    o_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("causal", "rows", "interpret"))
def softmax_rows(x, causal=False, rows=DEFAULT_ROW_BLOCK, interpret=True):
    """Row softmax of a 2-D ``x`` (R, N). With ``causal=True``, ``R`` must
    be a multiple of ``N`` (flattened (batch·seq, seq) attention scores)
    and entry (r, c) is masked out when ``c > r % N``."""
    assert x.ndim == 2
    r, n = x.shape
    if causal:
        assert r % n == 0, "causal softmax expects (batch*seq, seq) scores"
    rows_eff = min(rows, r)
    pad = (-r) % rows_eff
    xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
    out = pl.pallas_call(
        functools.partial(_softmax_kernel, rows=rows_eff, causal=causal),
        grid=(xp.shape[0] // rows_eff,),
        in_specs=[pl.BlockSpec((rows_eff, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows_eff, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        interpret=interpret,
    )(xp)
    return out[:r]
