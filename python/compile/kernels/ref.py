"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

The pytest suite asserts `kernels.<k>(...) ≈ ref.<k>(...)` over a
hypothesis-driven sweep of shapes; the L2 model is additionally checked
end-to-end against a reference model built exclusively from these.
"""

import jax.numpy as jnp


def matmul(x, y, bias=None, activation=None):
    """`x @ y (+ bias) (∘ activation)` in fp32."""
    out = jnp.matmul(x, y)
    if bias is not None:
        out = out + bias
    if activation == "gelu":
        out = gelu(out)
    elif activation is not None:
        raise ValueError(f"unknown activation {activation}")
    return out


def gelu(x):
    """tanh-approximated GELU (matches the kernel's epilogue)."""
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def layernorm(x, gamma, beta, eps=1e-5):
    """Row-wise LayerNorm over the last axis."""
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * gamma + beta


def softmax_rows(x, mask=None):
    """Numerically stable row softmax; `mask` (broadcastable, bool) marks
    positions kept — masked-out entries get probability 0."""
    if mask is not None:
        x = jnp.where(mask, x, jnp.finfo(x.dtype).min)
    x = x - jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def sgd_update(param, grad, lr):
    """Vanilla SGD step."""
    return param - lr * grad
