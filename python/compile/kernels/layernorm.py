"""Layer-1 Pallas kernel: fused row-wise LayerNorm.

CUDA implementations reduce within a warp via shuffles; on a VMEM machine
the whole feature row fits in one block, so mean/variance/normalize/affine
fuse into a single VMEM-resident pass over a (rows_block, D) tile.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_ROW_BLOCK = 128


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps):
    x = x_ref[...]
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    o_ref[...] = (x - mean) * jax.lax.rsqrt(var + eps) * g_ref[...] + b_ref[...]


@functools.partial(jax.jit, static_argnames=("eps", "rows", "interpret"))
def layernorm(x, gamma, beta, eps=1e-5, rows=DEFAULT_ROW_BLOCK, interpret=True):
    """LayerNorm over the last axis of a 2-D ``x`` (R, D)."""
    assert x.ndim == 2
    r, d = x.shape
    assert gamma.shape == (d,) and beta.shape == (d,)
    rows = min(rows, r)
    pad = (-r) % rows
    xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
    grid = (xp.shape[0] // rows,)
    out = pl.pallas_call(
        functools.partial(_layernorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        interpret=interpret,
    )(xp, gamma, beta)
    return out[:r]
