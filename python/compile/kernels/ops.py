"""Differentiable wrappers around the Pallas kernels.

``pl.pallas_call`` has no automatic VJP, so each op defines its backward
pass explicitly — with the backward matmuls routed through the same Pallas
matmul kernel, keeping the MXU path on both sides of autodiff (this is
what cuDNN does with dedicated dgrad/wgrad kernels).
"""

import jax
import jax.numpy as jnp

from . import matmul as mm
from . import layernorm as ln
from . import softmax as sm
from . import ref


# --------------------------------------------------------------------------
# matmul (+bias, +gelu)
# --------------------------------------------------------------------------


def _gelu_grad(z):
    """d/dz gelu(z) for the tanh approximation used in the kernel."""
    c = jnp.sqrt(2.0 / jnp.pi).astype(z.dtype)
    u = c * (z + 0.044715 * z**3)
    t = jnp.tanh(u)
    du = c * (1.0 + 3 * 0.044715 * z**2)
    return 0.5 * (1.0 + t) + 0.5 * z * (1.0 - t**2) * du


@jax.custom_vjp
def matmul(x, y, bias):
    return mm.matmul(x, y, bias=bias)


def _matmul_fwd(x, y, bias):
    return mm.matmul(x, y, bias=bias), (x, y)


def _matmul_bwd(res, dout):
    x, y = res
    dx = mm.matmul(dout, y.T)
    dy = mm.matmul(x.T, dout)
    db = jnp.sum(dout, axis=0)
    return dx, dy, db


matmul.defvjp(_matmul_fwd, _matmul_bwd)


@jax.custom_vjp
def matmul_gelu(x, y, bias):
    return mm.matmul(x, y, bias=bias, activation="gelu")


def _matmul_gelu_fwd(x, y, bias):
    # Rematerialize z = x@y+b in the backward instead of saving it
    # (memory-for-compute, the standard epilogue-fusion trade).
    return mm.matmul(x, y, bias=bias, activation="gelu"), (x, y, bias)


def _matmul_gelu_bwd(res, dout):
    x, y, bias = res
    z = mm.matmul(x, y, bias=bias)
    dz = dout * _gelu_grad(z)
    dx = mm.matmul(dz, y.T)
    dy = mm.matmul(x.T, dz)
    db = jnp.sum(dz, axis=0)
    return dx, dy, db


matmul_gelu.defvjp(_matmul_gelu_fwd, _matmul_gelu_bwd)


# --------------------------------------------------------------------------
# layernorm
# --------------------------------------------------------------------------


@jax.custom_vjp
def layernorm(x, gamma, beta):
    return ln.layernorm(x, gamma, beta)


def _layernorm_fwd(x, gamma, beta):
    return ln.layernorm(x, gamma, beta), (x, gamma)


def _layernorm_bwd(res, dout):
    x, gamma = res
    eps = 1e-5
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    xhat = (x - mean) * inv
    dg = jnp.sum(dout * xhat, axis=0)
    db = jnp.sum(dout, axis=0)
    dxhat = dout * gamma
    dx = inv * (
        dxhat
        - jnp.mean(dxhat, axis=-1, keepdims=True)
        - xhat * jnp.mean(dxhat * xhat, axis=-1, keepdims=True)
    )
    return dx, dg, db


layernorm.defvjp(_layernorm_fwd, _layernorm_bwd)


# --------------------------------------------------------------------------
# causal softmax
# --------------------------------------------------------------------------


@jax.custom_vjp
def causal_softmax(x):
    return sm.softmax_rows(x, causal=True)


def _causal_softmax_fwd(x):
    p = sm.softmax_rows(x, causal=True)
    return p, (p,)


def _causal_softmax_bwd(res, dout):
    (p,) = res
    # Masked entries have p = 0, so their dx is 0 automatically.
    dx = p * (dout - jnp.sum(dout * p, axis=-1, keepdims=True))
    return (dx,)


causal_softmax.defvjp(_causal_softmax_fwd, _causal_softmax_bwd)


# --------------------------------------------------------------------------
# reference (pure-jnp) twins used by the model-level equivalence test
# --------------------------------------------------------------------------


def matmul_ref(x, y, bias):
    return ref.matmul(x, y, bias=bias)


def matmul_gelu_ref(x, y, bias):
    return ref.matmul(x, y, bias=bias, activation="gelu")


def layernorm_ref(x, gamma, beta):
    return ref.layernorm(x, gamma, beta)


def causal_softmax_ref(x):
    r, n = x.shape
    row = jnp.arange(r)[:, None] % n
    col = jnp.arange(n)[None, :]
    return ref.softmax_rows(x, mask=col <= row)
