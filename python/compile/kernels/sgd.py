"""Layer-1 Pallas kernel: fused SGD parameter update.

`p ← p − lr·g` over a flat fp32 vector, tiled into VMEM chunks — the
paper's step-6 "update" task as a single bandwidth-bound kernel (its
CUDA counterpart is a grid-stride elementwise kernel).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_CHUNK = 16_384


def _sgd_kernel(p_ref, g_ref, lr_ref, o_ref):
    o_ref[...] = p_ref[...] - lr_ref[0] * g_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def sgd_update(param, grad, lr, chunk=DEFAULT_CHUNK, interpret=True):
    """SGD step on tensors of any shape (flattened internally)."""
    assert param.shape == grad.shape
    flat_p = param.reshape(-1)
    flat_g = grad.reshape(-1)
    n = flat_p.shape[0]
    c = min(chunk, n)
    pad = (-n) % c
    if pad:
        flat_p = jnp.pad(flat_p, (0, pad))
        flat_g = jnp.pad(flat_g, (0, pad))
    lr_arr = jnp.asarray([lr], dtype=param.dtype)
    out = pl.pallas_call(
        _sgd_kernel,
        grid=(flat_p.shape[0] // c,),
        in_specs=[
            pl.BlockSpec((c,), lambda i: (i,)),
            pl.BlockSpec((c,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((c,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(flat_p.shape, param.dtype),
        interpret=interpret,
    )(flat_p, flat_g, lr_arr)
    return out[:n].reshape(param.shape)
