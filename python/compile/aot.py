"""AOT compile path: lower the L2 train/update steps to HLO **text** and
dump initial parameters + metadata for the Rust runtime.

Run once by `make artifacts`; the Rust binary is self-contained afterwards.

HLO text — not ``.serialize()`` — is the interchange format: jax ≥ 0.5
emits HloModuleProtos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out-dir ../artifacts [--d-model 128 ...]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_train_step(cfg: model.Config) -> str:
    spec = model.param_spec(cfg)
    args = [jax.ShapeDtypeStruct(shape, jnp.float32) for _, shape in spec]
    args.append(jax.ShapeDtypeStruct((cfg.batch, cfg.seq), jnp.int32))  # tokens
    args.append(jax.ShapeDtypeStruct((cfg.batch, cfg.seq), jnp.int32))  # targets
    return to_hlo_text(jax.jit(model.make_train_step(cfg)).lower(*args))


def lower_update_step(cfg: model.Config) -> str:
    spec = model.param_spec(cfg)
    args = [jax.ShapeDtypeStruct(shape, jnp.float32) for _, shape in spec] * 2
    return to_hlo_text(jax.jit(model.make_update_step(cfg)).lower(*args))


def write_params(cfg: model.Config, path: str, seed: int) -> list:
    """Dump initial parameters as one flat little-endian f32 blob; return
    the parameter table (name, shape, numel, offset-in-floats)."""
    params = model.init_params(cfg, seed=seed)
    table = []
    offset = 0
    with open(path, "wb") as f:
        for (name, shape), p in zip(model.param_spec(cfg), params):
            arr = np.asarray(p, dtype="<f4")
            f.write(arr.tobytes())
            table.append(
                {
                    "name": name,
                    "shape": list(shape),
                    "numel": int(arr.size),
                    "offset": offset,
                }
            )
            offset += int(arr.size)
    return table


def build(cfg: model.Config, out_dir: str, seed: int = 0) -> dict:
    os.makedirs(out_dir, exist_ok=True)

    train_hlo = lower_train_step(cfg)
    with open(os.path.join(out_dir, "train_step.hlo.txt"), "w") as f:
        f.write(train_hlo)

    update_hlo = lower_update_step(cfg)
    with open(os.path.join(out_dir, "update_step.hlo.txt"), "w") as f:
        f.write(update_hlo)

    table = write_params(cfg, os.path.join(out_dir, "params.bin"), seed)

    meta = {
        "config": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_heads": cfg.n_heads,
            "n_layers": cfg.n_layers,
            "seq": cfg.seq,
            "batch": cfg.batch,
            "lr": cfg.lr,
        },
        "params": table,
        "total_params": sum(t["numel"] for t in table),
        "artifacts": {
            "train_step": "train_step.hlo.txt",
            "update_step": "update_step.hlo.txt",
            "params": "params.bin",
        },
    }
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    return meta


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--n-heads", type=int, default=4)
    ap.add_argument("--n-layers", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    cfg = model.Config(
        vocab=args.vocab,
        d_model=args.d_model,
        n_heads=args.n_heads,
        n_layers=args.n_layers,
        seq=args.seq,
        batch=args.batch,
        lr=args.lr,
    )
    meta = build(cfg, args.out_dir, seed=args.seed)
    print(
        f"wrote artifacts to {args.out_dir}: "
        f"{meta['total_params']} parameters, "
        f"{len(meta['params'])} tensors"
    )


if __name__ == "__main__":
    main()
